//! PCG-XSL-RR 128/64 — O'Neill's PCG family member with 128-bit state.
//!
//! Chosen for its excellent statistical quality, 2^128 period, trivially
//! splittable streams (odd increments select independent sequences), and
//! a ~3ns/u64 hot path.

use super::{RngCore, SplitMix64};

const MULTIPLIER: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

/// `(A, S)` jump tables for the underlying LCG: advancing the state `i`
/// steps is the affine map `s ↦ A[i]·s + S[i]·increment (mod 2^128)`,
/// because `advance(s) = M·s + inc` composes to
/// `advance^i(s) = M^i·s + (M^{i-1} + … + M + 1)·inc`. Indices cover
/// `0..=64` — one machine word of lanes, the most
/// [`Pcg64::fill_f64`] ever needs.
const fn lcg_jump_tables() -> ([u128; 65], [u128; 65]) {
    let mut a = [0u128; 65];
    let mut s = [0u128; 65];
    a[0] = 1;
    let mut i = 1;
    while i <= 64 {
        a[i] = a[i - 1].wrapping_mul(MULTIPLIER);
        s[i] = s[i - 1].wrapping_mul(MULTIPLIER).wrapping_add(1);
        i += 1;
    }
    (a, s)
}

/// See [`lcg_jump_tables`].
const JUMP: ([u128; 65], [u128; 65]) = lcg_jump_tables();

/// Number of independent jump-ahead chains [`Pcg64::fill_f64`] runs: one
/// per lane tile (the engine's tile width asserts equality at compile
/// time), so eight 128-bit multiply chains are in flight instead of one
/// serial dependency chain.
pub(crate) const FILL_CHAINS: usize = 8;

/// PCG-XSL-RR 128/64 generator.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    increment: u128, // must be odd
}

impl Pcg64 {
    /// Construct from a full (state, stream) pair.
    pub fn new(state: u128, stream: u128) -> Self {
        let mut rng = Self {
            state: 0,
            increment: (stream << 1) | 1,
        };
        // standard PCG seeding dance
        rng.step();
        rng.state = rng.state.wrapping_add(state);
        rng.step();
        rng
    }

    /// Convenience seeding from a single u64 via SplitMix64 expansion.
    pub fn seed(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let state = ((sm.next_u64() as u128) << 64) | sm.next_u64() as u128;
        let stream = ((sm.next_u64() as u128) << 64) | sm.next_u64() as u128;
        Self::new(state, stream)
    }

    /// Derive an independent generator for a parallel worker.
    ///
    /// Distinct `stream_id`s select distinct PCG sequences (different odd
    /// increments), which are statistically independent — this is how the
    /// thread-parallel samplers give every worker its own stream while
    /// staying fully reproducible from one experiment seed.
    pub fn split(&self, stream_id: u64) -> Self {
        let mut sm = SplitMix64::new(
            (self.increment >> 1) as u64 ^ stream_id.wrapping_mul(0x9e37_79b9_7f4a_7c15),
        );
        let state = ((sm.next_u64() as u128) << 64) | sm.next_u64() as u128;
        let stream = ((sm.next_u64() as u128) << 64)
            | sm.next_u64() as u128 ^ stream_id as u128;
        Self::new(state, stream)
    }

    /// Derive a stream from a two-dimensional key — e.g. `(sweep, site)`.
    ///
    /// Grid-shaped parallel structures need one independent stream per
    /// cell; packing the pair into [`Pcg64::split`]'s single index with
    /// arithmetic like `a·K + b` silently collides once `b` can exceed
    /// `K`. Here the coordinates are mixed with distinct odd multipliers
    /// (wyhash primes) before the usual split derivation, so distinct
    /// pairs collide only with the generic 2⁻⁶⁴ hashing probability —
    /// negligible over any realistic `sweeps × sites` domain. The lane
    /// engine keys every site's draws by `(sweep, site)` through this,
    /// which is what makes its sweeps pool-size-invariant.
    pub fn split2(&self, a: u64, b: u64) -> Self {
        let mixed = a
            .wrapping_mul(0xA076_1D64_78BD_642F)
            .wrapping_add(b.wrapping_mul(0xE703_7ED1_A0B4_28DB))
            .rotate_left(23)
            ^ b;
        self.split(mixed)
    }

    #[inline]
    fn step(&mut self) {
        self.state = self
            .state
            .wrapping_mul(MULTIPLIER)
            .wrapping_add(self.increment);
    }

    /// XSL-RR output function: xor-fold the state halves, rotate by the
    /// top bits. Shared by [`RngCore::next_u64`] and [`Pcg64::fill_f64`]
    /// so both produce identical draws from identical states.
    #[inline]
    fn output(state: u128) -> u64 {
        let rot = (state >> 122) as u32;
        let xored = ((state >> 64) as u64) ^ (state as u64);
        xored.rotate_right(rot)
    }

    /// `next_f64`'s mantissa mapping, applied to a raw output word.
    #[inline]
    fn to_f64(x: u64) -> f64 {
        (x >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Fill `out[..k]` with the next `k` uniform draws — **bit-identical**
    /// to `k` successive [`RngCore::next_f64`] calls (same values, same
    /// final generator state), but computed on eight independent
    /// jump-ahead chains.
    ///
    /// A single LCG is a serial dependency chain: draw `i + 1` cannot
    /// start its 128-bit multiply before draw `i` finishes. Chain `j`
    /// here starts at `advance^{j+1}(s₀)` (one constant affine map from
    /// precomputed jump tables, no serial warm-up) and then advances by
    /// `advance^8` per round, so it produces exactly draws
    /// `j, j+8, j+16, …` of the sequential sequence while the other
    /// seven chains run concurrently in the CPU's multiply pipeline.
    /// This is the SIMD-tiled lane kernels' uniform source: the per-lane
    /// draw order (and hence the sampled trajectory) is untouched, only
    /// the instruction-level parallelism changes.
    ///
    /// `k` is capped at 64 (one packed lane word, the tables' range).
    pub fn fill_f64(&mut self, out: &mut [f64; 64], k: usize) {
        assert!(k <= 64, "fill_f64 serves at most one 64-lane word");
        if k < FILL_CHAINS {
            // short tail word (e.g. 65 lanes → k = 1): chain setup would
            // cost more multiplies than it saves — step sequentially,
            // which is the definition the chains reproduce anyway
            for o in out[..k].iter_mut() {
                self.step();
                *o = Self::to_f64(Self::output(self.state));
            }
            return;
        }
        let (jump_a, jump_s) = (&JUMP.0, &JUMP.1);
        let (s0, inc) = (self.state, self.increment);
        // chain j ↦ state after j+1 steps (the state draw j is output from)
        let mut chain = [0u128; FILL_CHAINS];
        for (j, c) in chain.iter_mut().enumerate() {
            *c = jump_a[j + 1]
                .wrapping_mul(s0)
                .wrapping_add(jump_s[j + 1].wrapping_mul(inc));
        }
        let a8 = jump_a[FILL_CHAINS];
        let c8 = jump_s[FILL_CHAINS].wrapping_mul(inc);
        let mut i = 0;
        while i + FILL_CHAINS <= k {
            // full round: 8 independent output+advance chains
            for (o, c) in out[i..i + FILL_CHAINS].iter_mut().zip(chain.iter_mut()) {
                *o = Self::to_f64(Self::output(*c));
                *c = a8.wrapping_mul(*c).wrapping_add(c8);
            }
            i += FILL_CHAINS;
        }
        // tail round: the first k - i chains already hold the right states
        for (o, c) in out[i..k].iter_mut().zip(chain.iter()) {
            *o = Self::to_f64(Self::output(*c));
        }
        // land exactly where k sequential steps would have
        self.state = jump_a[k]
            .wrapping_mul(s0)
            .wrapping_add(jump_s[k].wrapping_mul(inc));
    }
}

impl RngCore for Pcg64 {
    #[inline]
    fn next_u64(&mut self) -> u64 {
        self.step();
        Self::output(self.state)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg64::seed(42);
        let mut b = Pcg64::seed(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Pcg64::seed(1);
        let mut b = Pcg64::seed(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn split_streams_diverge_and_are_deterministic() {
        let base = Pcg64::seed(7);
        let mut s1 = base.split(1);
        let mut s2 = base.split(2);
        let mut s1b = base.split(1);
        for _ in 0..64 {
            let v1 = s1.next_u64();
            assert_eq!(v1, s1b.next_u64());
            assert_ne!(v1, s2.next_u64());
        }
    }

    #[test]
    fn split2_deterministic_and_pairwise_distinct() {
        let base = Pcg64::seed(11);
        // replaying the same key gives the same stream
        let mut a = base.split2(3, 7);
        let mut b = base.split2(3, 7);
        for _ in 0..32 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        // a small grid of keys yields pairwise-distinct first draws
        let mut seen = Vec::new();
        for i in 0..16u64 {
            for j in 0..16u64 {
                seen.push(base.split2(i, j).next_u64());
            }
        }
        let mut sorted = seen.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), seen.len(), "split2 stream collision");
        // and differs from the 1-D split on the same leading index
        assert_ne!(base.split2(5, 0).next_u64(), base.split(5).next_u64());
    }

    #[test]
    fn fill_f64_is_bit_identical_to_sequential_draws() {
        // the tiled kernels' whole determinism story rests on this: the
        // jump-ahead fill must reproduce next_f64 draw-for-draw AND leave
        // the generator in the exact same state, for every k 0..=64
        // (tails of every length) and across derived streams
        for seed in [0u64, 1, 42, 0xDEAD_BEEF] {
            for k in 0..=64usize {
                let mut seq = Pcg64::seed(seed).split2(3, k as u64);
                let mut jmp = seq.clone();
                let want: Vec<f64> = (0..k).map(|_| seq.next_f64()).collect();
                let mut out = [0.0f64; 64];
                jmp.fill_f64(&mut out, k);
                for (l, w) in want.iter().enumerate() {
                    assert!(
                        out[l].to_bits() == w.to_bits(),
                        "seed {seed} k {k} draw {l}: {} vs {}",
                        out[l],
                        w
                    );
                }
                // post-state equality: the next draws must also agree
                for i in 0..8 {
                    assert_eq!(seq.next_u64(), jmp.next_u64(), "seed {seed} k {k} post {i}");
                }
            }
        }
    }

    #[test]
    fn fill_f64_zero_draws_is_a_noop() {
        let mut a = Pcg64::seed(5);
        let mut b = a.clone();
        let mut out = [0.5f64; 64];
        a.fill_f64(&mut out, 0);
        assert_eq!(out, [0.5f64; 64], "no lanes may be written");
        assert_eq!(a.next_u64(), b.next_u64(), "state must be untouched");
    }

    #[test]
    fn no_short_cycle() {
        let mut rng = Pcg64::seed(9);
        let first = rng.next_u64();
        // a cycle of < 1e5 would be catastrophic; PCG's period is 2^128
        let hit = (0..100_000).any(|_| rng.next_u64() == first);
        // values may repeat by chance (birthday ~ 1e-9 here); state may not.
        // This is a smoke check, not a period proof.
        let _ = hit;
        assert_ne!(rng.next_u64(), rng.next_u64());
    }
}
