//! PCG-XSL-RR 128/64 — O'Neill's PCG family member with 128-bit state.
//!
//! Chosen for its excellent statistical quality, 2^128 period, trivially
//! splittable streams (odd increments select independent sequences), and
//! a ~3ns/u64 hot path.

use super::{RngCore, SplitMix64};

const MULTIPLIER: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

/// PCG-XSL-RR 128/64 generator.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    increment: u128, // must be odd
}

impl Pcg64 {
    /// Construct from a full (state, stream) pair.
    pub fn new(state: u128, stream: u128) -> Self {
        let mut rng = Self {
            state: 0,
            increment: (stream << 1) | 1,
        };
        // standard PCG seeding dance
        rng.step();
        rng.state = rng.state.wrapping_add(state);
        rng.step();
        rng
    }

    /// Convenience seeding from a single u64 via SplitMix64 expansion.
    pub fn seed(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let state = ((sm.next_u64() as u128) << 64) | sm.next_u64() as u128;
        let stream = ((sm.next_u64() as u128) << 64) | sm.next_u64() as u128;
        Self::new(state, stream)
    }

    /// Derive an independent generator for a parallel worker.
    ///
    /// Distinct `stream_id`s select distinct PCG sequences (different odd
    /// increments), which are statistically independent — this is how the
    /// thread-parallel samplers give every worker its own stream while
    /// staying fully reproducible from one experiment seed.
    pub fn split(&self, stream_id: u64) -> Self {
        let mut sm = SplitMix64::new(
            (self.increment >> 1) as u64 ^ stream_id.wrapping_mul(0x9e37_79b9_7f4a_7c15),
        );
        let state = ((sm.next_u64() as u128) << 64) | sm.next_u64() as u128;
        let stream = ((sm.next_u64() as u128) << 64)
            | sm.next_u64() as u128 ^ stream_id as u128;
        Self::new(state, stream)
    }

    /// Derive a stream from a two-dimensional key — e.g. `(sweep, site)`.
    ///
    /// Grid-shaped parallel structures need one independent stream per
    /// cell; packing the pair into [`Pcg64::split`]'s single index with
    /// arithmetic like `a·K + b` silently collides once `b` can exceed
    /// `K`. Here the coordinates are mixed with distinct odd multipliers
    /// (wyhash primes) before the usual split derivation, so distinct
    /// pairs collide only with the generic 2⁻⁶⁴ hashing probability —
    /// negligible over any realistic `sweeps × sites` domain. The lane
    /// engine keys every site's draws by `(sweep, site)` through this,
    /// which is what makes its sweeps pool-size-invariant.
    pub fn split2(&self, a: u64, b: u64) -> Self {
        let mixed = a
            .wrapping_mul(0xA076_1D64_78BD_642F)
            .wrapping_add(b.wrapping_mul(0xE703_7ED1_A0B4_28DB))
            .rotate_left(23)
            ^ b;
        self.split(mixed)
    }

    #[inline]
    fn step(&mut self) {
        self.state = self
            .state
            .wrapping_mul(MULTIPLIER)
            .wrapping_add(self.increment);
    }
}

impl RngCore for Pcg64 {
    #[inline]
    fn next_u64(&mut self) -> u64 {
        self.step();
        // XSL-RR output function: xor-fold the halves, rotate by the top bits.
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg64::seed(42);
        let mut b = Pcg64::seed(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Pcg64::seed(1);
        let mut b = Pcg64::seed(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn split_streams_diverge_and_are_deterministic() {
        let base = Pcg64::seed(7);
        let mut s1 = base.split(1);
        let mut s2 = base.split(2);
        let mut s1b = base.split(1);
        for _ in 0..64 {
            let v1 = s1.next_u64();
            assert_eq!(v1, s1b.next_u64());
            assert_ne!(v1, s2.next_u64());
        }
    }

    #[test]
    fn split2_deterministic_and_pairwise_distinct() {
        let base = Pcg64::seed(11);
        // replaying the same key gives the same stream
        let mut a = base.split2(3, 7);
        let mut b = base.split2(3, 7);
        for _ in 0..32 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        // a small grid of keys yields pairwise-distinct first draws
        let mut seen = Vec::new();
        for i in 0..16u64 {
            for j in 0..16u64 {
                seen.push(base.split2(i, j).next_u64());
            }
        }
        let mut sorted = seen.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), seen.len(), "split2 stream collision");
        // and differs from the 1-D split on the same leading index
        assert_ne!(base.split2(5, 0).next_u64(), base.split(5).next_u64());
    }

    #[test]
    fn no_short_cycle() {
        let mut rng = Pcg64::seed(9);
        let first = rng.next_u64();
        // a cycle of < 1e5 would be catastrophic; PCG's period is 2^128
        let hit = (0..100_000).any(|_| rng.next_u64() == first);
        // values may repeat by chance (birthday ~ 1e-9 here); state may not.
        // This is a smoke check, not a period proof.
        let _ = hit;
        assert_ne!(rng.next_u64(), rng.next_u64());
    }
}
