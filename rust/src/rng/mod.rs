//! Deterministic, splittable pseudo-random number generation.
//!
//! The offline environment has no `rand` crate, so the crate ships its own
//! generators: [`Pcg64`] (the PCG-XSL-RR 128/64 member, the workhorse) and
//! [`SplitMix64`] (seeding / stream derivation). Both are tiny, fast, and
//! reproducible across platforms, which the experiment harness relies on:
//! every benchmark records its seed and can be replayed bit-for-bit.

mod pcg;
mod splitmix;

pub(crate) use pcg::FILL_CHAINS;
pub use pcg::Pcg64;
pub use splitmix::SplitMix64;

/// Minimal RNG interface used across the crate.
///
/// Implementors only supply [`RngCore::next_u64`]; the provided methods
/// derive uniforms, Bernoulli draws and categorical draws from it.
pub trait RngCore {
    /// Next 64 uniformly distributed bits.
    fn next_u64(&mut self) -> u64;

    /// Uniform in `[0, 1)` with 53 bits of mantissa.
    #[inline]
    fn next_f64(&mut self) -> f64 {
        // Take the top 53 bits — unbiased and exactly representable.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)` (24 bits of mantissa).
    #[inline]
    fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in `[0, n)` via Lemire's unbiased multiply-shift.
    #[inline]
    fn next_below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= n.wrapping_neg() % n {
                return (m >> 64) as u64;
            }
        }
    }

    /// Bernoulli draw with success probability `p`.
    #[inline]
    fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Draw an index from unnormalized non-negative weights.
    ///
    /// Panics in debug builds if all weights are zero or any is negative.
    fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0, "categorical weights must sum > 0");
        let mut t = self.next_f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            debug_assert!(w >= 0.0);
            t -= w;
            if t < 0.0 {
                return i;
            }
        }
        weights.len() - 1 // float roundoff fallthrough
    }

    /// Fisher–Yates shuffle.
    fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Standard normal via Box–Muller (cached second value is *not* kept to
    /// stay allocation- and state-free; fine for non-hot-path use).
    fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Poisson draw with the given mean (`mean ≥ 0`).
    ///
    /// Chunked Knuth multiplicative method: means above `POISSON_CHUNK` are
    /// split into independent Poisson draws of at most `POISSON_CHUNK` each
    /// (Poisson is additive), keeping `e^{-chunk}` well above f64 underflow.
    /// O(mean) uniforms per draw — exactly what the minibatch sweep path
    /// wants, since its means are the (small) per-site auxiliary rates.
    fn poisson(&mut self, mean: f64) -> u64 {
        /// Largest per-chunk mean; `e^{-500} ≈ 7e-218` is comfortably normal.
        const POISSON_CHUNK: f64 = 500.0;
        debug_assert!(mean >= 0.0 && mean.is_finite());
        let mut remaining = mean;
        let mut n = 0u64;
        loop {
            let chunk = remaining.min(POISSON_CHUNK);
            let limit = (-chunk).exp();
            let mut p = 1.0;
            loop {
                p *= self.next_f64();
                if p < limit {
                    break;
                }
                n += 1;
            }
            remaining -= chunk;
            if remaining <= 0.0 {
                return n;
            }
        }
    }
}

/// Logistic sigmoid; numerically stable on both tails.
#[inline]
pub fn sigmoid(z: f64) -> f64 {
    if z >= 0.0 {
        1.0 / (1.0 + (-z).exp())
    } else {
        let e = z.exp();
        e / (1.0 + e)
    }
}

/// `e^{-|z|}` with `|z|` clamped to 40, accurate to ~5e-9 relative — the
/// shared core of [`sigmoid_fast`] and [`bernoulli_sigmoid`].
///
/// Range-reduces to `2^k · e^u` with `|u| ≤ ln(2)/2` (so `2^k` is always a
/// normal f64 assembled from bits) and evaluates `e^u` as a degree-7
/// Taylor polynomial — no libm call.
#[inline]
fn exp_neg_abs(z: f64) -> f64 {
    // t = -|z|·log2(e) ∈ [-57.8, 0]
    let t = -z.abs().min(40.0) * std::f64::consts::LOG2_E;
    let k = t.round(); // k ∈ {-58, ..., 0}
    let u = (t - k) * std::f64::consts::LN_2; // |u| ≤ ln(2)/2 ≈ 0.347
    let mut e = 1.0 / 5040.0; // Taylor e^u, Horner
    e = e * u + 1.0 / 720.0;
    e = e * u + 1.0 / 120.0;
    e = e * u + 1.0 / 24.0;
    e = e * u + 1.0 / 6.0;
    e = e * u + 0.5;
    e = e * u + 1.0;
    e = e * u + 1.0;
    e * f64::from_bits(((k as i64 + 1023) as u64) << 52)
}

/// Fast logistic sigmoid for hot loops; absolute error < 1e-8 vs
/// [`sigmoid`]. `|z|` is clamped to 40 (σ saturates to within 4e-18 of
/// {0, 1} there). The scalar samplers keep the exact [`sigmoid`]; the lane
/// engine ([`crate::engine`]) uses this for its precomputed θ-conditional
/// tables.
#[inline]
pub fn sigmoid_fast(z: f64) -> f64 {
    let p = exp_neg_abs(z); // e^{-|z|} ∈ (0, 1]
    if z >= 0.0 {
        1.0 / (1.0 + p)
    } else {
        p / (1.0 + p)
    }
}

/// The `(mult, thresh)` pair behind [`bernoulli_sigmoid`]: with
/// `p = e^{-|z|}`, the acceptance `u < 1/(1+p)` (for `z ≥ 0`) is
/// `u·(1+p) < 1`, and `u < p/(1+p)` (for `z < 0`) is `u·(1+p) < p` — so
/// `mult = 1 + p` and `thresh = 1` or `p` by the sign of `z`.
///
/// The pair depends only on `z`, so callers whose `z` ranges over a small
/// set (the lane engine's per-site conditional tables) precompute it once
/// and draw via [`bernoulli_from_parts`] — bit-identical to calling
/// [`bernoulli_sigmoid`] with the same `z` and RNG state, because both go
/// through exactly this comparison.
#[inline]
pub fn bernoulli_sigmoid_parts(z: f64) -> (f64, f64) {
    let p = exp_neg_abs(z);
    (1.0 + p, if z >= 0.0 { 1.0 } else { p })
}

/// Draw from precomputed [`bernoulli_sigmoid_parts`]. One uniform, one
/// multiply, one compare — no exponential on the draw path.
#[inline]
pub fn bernoulli_from_parts<R: RngCore>(rng: &mut R, mult: f64, thresh: f64) -> bool {
    rng.next_f64() * mult < thresh
}

/// Draw `Bernoulli(sigmoid(z))` without any division (see
/// [`bernoulli_sigmoid_parts`] for the acceptance identity). Same
/// distribution as `rng.bernoulli(sigmoid_fast(z))` up to one ulp of
/// the comparison; this is the lane engine's per-lane hot path.
#[inline]
pub fn bernoulli_sigmoid<R: RngCore>(rng: &mut R, z: f64) -> bool {
    let (mult, thresh) = bernoulli_sigmoid_parts(z);
    bernoulli_from_parts(rng, mult, thresh)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_range_and_mean() {
        let mut rng = Pcg64::seed(1);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = rng.next_f64();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn next_below_unbiased_small_range() {
        let mut rng = Pcg64::seed(2);
        let mut counts = [0usize; 7];
        let n = 70_000;
        for _ in 0..n {
            counts[rng.next_below(7) as usize] += 1;
        }
        for &c in &counts {
            let expected = n / 7;
            assert!(
                (c as i64 - expected as i64).unsigned_abs() < 600,
                "counts={counts:?}"
            );
        }
    }

    #[test]
    fn bernoulli_frequency() {
        let mut rng = Pcg64::seed(3);
        let hits = (0..50_000).filter(|_| rng.bernoulli(0.3)).count();
        let freq = hits as f64 / 50_000.0;
        assert!((freq - 0.3).abs() < 0.01, "freq={freq}");
    }

    #[test]
    fn categorical_tracks_weights() {
        let mut rng = Pcg64::seed(4);
        let w = [1.0, 2.0, 3.0, 4.0];
        let mut counts = [0usize; 4];
        let n = 100_000;
        for _ in 0..n {
            counts[rng.categorical(&w)] += 1;
        }
        for (i, &c) in counts.iter().enumerate() {
            let p = w[i] / 10.0;
            assert!((c as f64 / n as f64 - p).abs() < 0.01);
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::seed(5);
        let n = 50_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let z = rng.normal();
            s += z;
            s2 += z * z;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn poisson_moments_small_mean() {
        let mut rng = Pcg64::seed(21);
        for &mean in &[0.5, 4.0, 12.0] {
            let n = 60_000;
            let (mut s, mut s2) = (0.0, 0.0);
            for _ in 0..n {
                let k = rng.poisson(mean) as f64;
                s += k;
                s2 += k * k;
            }
            let m = s / n as f64;
            let var = s2 / n as f64 - m * m;
            // mean and variance of Poisson(mean) are both `mean`
            assert!((m - mean).abs() < 0.15 * mean.max(0.5), "mean {m} vs {mean}");
            assert!((var - mean).abs() < 0.15 * mean.max(0.5), "var {var} vs {mean}");
        }
    }

    #[test]
    fn poisson_chunked_large_mean() {
        // means above the chunk size exercise the additive split
        let mut rng = Pcg64::seed(22);
        let mean = 1300.5;
        let n = 4_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let k = rng.poisson(mean) as f64;
            s += k;
            s2 += k * k;
        }
        let m = s / n as f64;
        let var = s2 / n as f64 - m * m;
        assert!((m - mean).abs() < 3.0, "mean {m} vs {mean}");
        assert!((var / mean - 1.0).abs() < 0.12, "var {var} vs {mean}");
    }

    #[test]
    fn poisson_zero_mean_is_zero() {
        let mut rng = Pcg64::seed(23);
        for _ in 0..100 {
            assert_eq!(rng.poisson(0.0), 0);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::seed(6);
        let mut v: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sigmoid_stability() {
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-15);
        assert!(sigmoid(-800.0) >= 0.0);
        assert!(sigmoid(800.0) <= 1.0);
        assert!((sigmoid(2.0) + sigmoid(-2.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn sigmoid_fast_tracks_exact() {
        // dense grid over the interesting range plus the clamp region
        let mut z = -50.0;
        while z <= 50.0 {
            let (fast, exact) = (sigmoid_fast(z), sigmoid(z));
            assert!(
                (fast - exact).abs() < 1e-8,
                "z={z}: fast {fast} vs exact {exact}"
            );
            z += 0.0137;
        }
        assert!((sigmoid_fast(0.0) - 0.5).abs() < 1e-12);
        assert!(sigmoid_fast(1e6) <= 1.0 && sigmoid_fast(1e6) > 0.999);
        assert!(sigmoid_fast(-1e6) >= 0.0 && sigmoid_fast(-1e6) < 1e-3);
        // complementarity, like the exact version
        assert!((sigmoid_fast(1.7) + sigmoid_fast(-1.7) - 1.0).abs() < 1e-8);
    }

    #[test]
    fn bernoulli_sigmoid_frequency() {
        let mut rng = Pcg64::seed(13);
        for &z in &[-2.0, -0.4, 0.0, 0.7, 1.9] {
            let n = 60_000;
            let hits = (0..n).filter(|_| bernoulli_sigmoid(&mut rng, z)).count();
            let freq = hits as f64 / n as f64;
            let want = sigmoid(z);
            assert!(
                (freq - want).abs() < 0.01,
                "z={z}: freq {freq} vs sigmoid {want}"
            );
        }
    }

    #[test]
    fn parts_draws_are_bit_identical_to_bernoulli_sigmoid() {
        // the lane engine's cached tables go through bernoulli_from_parts;
        // the fallback path through bernoulli_sigmoid — the two must agree
        // draw-for-draw from the same RNG state for every z
        for &z in &[-5.0, -1.3, -0.0, 0.0, 0.25, 2.0, 41.0] {
            let mut a = Pcg64::seed(99);
            let mut b = Pcg64::seed(99);
            let (mult, thresh) = bernoulli_sigmoid_parts(z);
            for _ in 0..500 {
                assert_eq!(
                    bernoulli_sigmoid(&mut a, z),
                    bernoulli_from_parts(&mut b, mult, thresh),
                    "z={z}"
                );
            }
        }
    }
}
