//! Graph coloring — the *baseline* the paper argues against.
//!
//! Chromatic parallel Gibbs [Gonzalez et al., AISTATS 2011] colors the
//! variable-adjacency graph and resamples each color class in parallel.
//! Finding a minimal coloring is NP-hard [Garey–Johnson–Stockmeyer 1974];
//! we implement the two standard heuristics (greedy-by-order and DSATUR)
//! plus the *maintenance cost model* the dynamic benchmark measures: on
//! factor insertion the coloring may become invalid and must be repaired.

use super::{FactorGraph, VarId};

/// A proper coloring: `color[v]` with `num_colors` classes.
#[derive(Clone, Debug)]
pub struct Coloring {
    /// Color class of each variable.
    pub color: Vec<u32>,
    /// Number of distinct classes used.
    pub num_colors: u32,
    /// Topology version of the graph this coloring was computed for.
    pub version: u64,
}

impl Coloring {
    /// Variables grouped per color class (parallel-sweep schedule).
    pub fn classes(&self) -> Vec<Vec<VarId>> {
        let mut out = vec![Vec::new(); self.num_colors as usize];
        for (v, &c) in self.color.iter().enumerate() {
            out[c as usize].push(v);
        }
        out
    }

    /// Check properness against the current graph.
    pub fn is_proper(&self, g: &FactorGraph) -> bool {
        g.factors()
            .all(|(_, f)| self.color[f.v1] != self.color[f.v2])
    }
}

/// Greedy coloring in natural variable order. For a 2-colorable grid
/// visited row-major this recovers the checkerboard 2-coloring.
pub fn greedy(g: &FactorGraph) -> Coloring {
    color_in_order(g, (0..g.num_vars()).collect())
}

/// DSATUR (saturation-degree) heuristic — usually fewer colors on
/// irregular graphs at O((V+E) log V) cost.
pub fn dsatur(g: &FactorGraph) -> Coloring {
    let n = g.num_vars();
    let mut color = vec![u32::MAX; n];
    let mut saturation: Vec<std::collections::BTreeSet<u32>> =
        vec![std::collections::BTreeSet::new(); n];
    let mut num_colors = 0u32;

    // heap keyed by (saturation, degree); BTreeSet as a priority structure
    // with updatable keys.
    let mut heap: std::collections::BTreeSet<(usize, usize, VarId)> = (0..n)
        .map(|v| (0usize, g.degree(v), v))
        .collect();

    while let Some(&(sat, deg, v)) = heap.iter().next_back() {
        heap.remove(&(sat, deg, v));
        if color[v] != u32::MAX {
            continue;
        }
        let c = smallest_free_color(&saturation[v]);
        color[v] = c;
        num_colors = num_colors.max(c + 1);
        for u in g.neighbors(v) {
            if color[u] == u32::MAX && saturation[u].insert(c) {
                let old = (saturation[u].len() - 1, g.degree(u), u);
                heap.remove(&old);
                heap.insert((saturation[u].len(), g.degree(u), u));
            }
        }
    }
    Coloring {
        color,
        num_colors: num_colors.max(if n > 0 { 1 } else { 0 }),
        version: g.version(),
    }
}

fn color_in_order(g: &FactorGraph, order: Vec<VarId>) -> Coloring {
    let n = g.num_vars();
    let mut color = vec![u32::MAX; n];
    let mut num_colors = 0u32;
    let mut used = std::collections::BTreeSet::new();
    for v in order {
        used.clear();
        for u in g.neighbors(v) {
            if color[u] != u32::MAX {
                used.insert(color[u]);
            }
        }
        let c = smallest_free_color(&used);
        color[v] = c;
        num_colors = num_colors.max(c + 1);
    }
    Coloring {
        color,
        num_colors: num_colors.max(if n > 0 { 1 } else { 0 }),
        version: g.version(),
    }
}

fn smallest_free_color(used: &std::collections::BTreeSet<u32>) -> u32 {
    let mut c = 0u32;
    for &u in used {
        if u == c {
            c += 1;
        } else if u > c {
            break;
        }
    }
    c
}

/// Incremental repair after topology mutations: recolor only conflicted
/// variables (may add colors). Returns the number of variables touched —
/// the *maintenance cost* reported by the dynamic benchmark.
pub fn repair(g: &FactorGraph, coloring: &mut Coloring) -> usize {
    let mut touched = 0;
    // collect conflicted variables (one endpoint per conflicting factor)
    let conflicted: Vec<VarId> = g
        .factors()
        .filter(|(_, f)| coloring.color[f.v1] == coloring.color[f.v2])
        .map(|(_, f)| f.v2)
        .collect();
    let mut used = std::collections::BTreeSet::new();
    for v in conflicted {
        if coloring.color[v] == u32::MAX
            || g.neighbors(v)
                .iter()
                .any(|&u| coloring.color[u] == coloring.color[v])
        {
            used.clear();
            for u in g.neighbors(v) {
                used.insert(coloring.color[u]);
            }
            let c = smallest_free_color(&used);
            coloring.color[v] = c;
            coloring.num_colors = coloring.num_colors.max(c + 1);
            touched += 1;
        }
    }
    // grown variables (add_var) default to color 0; extend vector if needed
    while coloring.color.len() < g.num_vars() {
        coloring.color.push(0);
        touched += 1;
    }
    coloring.version = g.version();
    touched
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::PairFactor;
    use crate::util::proptest::{check, Gen};
    use crate::workloads;

    #[test]
    fn grid_is_two_colored_by_greedy() {
        let g = workloads::ising_grid(6, 6, 0.3, 0.0);
        let c = greedy(&g);
        assert!(c.is_proper(&g));
        assert_eq!(c.num_colors, 2);
    }

    #[test]
    fn dsatur_on_grid() {
        let g = workloads::ising_grid(5, 7, 0.3, 0.0);
        let c = dsatur(&g);
        assert!(c.is_proper(&g));
        assert!(c.num_colors <= 3, "num_colors={}", c.num_colors);
    }

    #[test]
    fn complete_graph_needs_n_colors() {
        let g = workloads::fully_connected_ising(6, |_, _| 0.1);
        for c in [greedy(&g), dsatur(&g)] {
            assert!(c.is_proper(&g));
            assert_eq!(c.num_colors, 6);
        }
    }

    #[test]
    fn classes_partition_vars() {
        let g = workloads::ising_grid(4, 4, 0.2, 0.0);
        let c = greedy(&g);
        let total: usize = c.classes().iter().map(Vec::len).sum();
        assert_eq!(total, g.num_vars());
    }

    #[test]
    fn repair_fixes_inserted_conflict() {
        let mut g = workloads::ising_grid(4, 4, 0.2, 0.0);
        let mut c = greedy(&g);
        assert!(c.is_proper(&g));
        // diagonal edge creates a same-color conflict on the checkerboard
        g.add_factor(PairFactor::ising(0, 5, 0.2));
        assert!(!c.is_proper(&g));
        let touched = repair(&g, &mut c);
        assert!(touched >= 1);
        assert!(c.is_proper(&g));
    }

    #[test]
    fn prop_colorings_always_proper() {
        check("greedy/dsatur proper on random graphs", 30, |g: &mut Gen| {
            let n = g.usize_in(2..=30);
            let mut fg = crate::graph::FactorGraph::new(n);
            for _ in 0..g.usize_in(1..=80) {
                let v1 = g.usize_in(0..=n - 1);
                let mut v2 = g.usize_in(0..=n - 1);
                if v1 == v2 {
                    v2 = (v2 + 1) % n;
                }
                fg.add_factor(PairFactor::ising(v1, v2, 0.1));
            }
            for c in [greedy(&fg), dsatur(&fg)] {
                if !c.is_proper(&fg) {
                    return Err("improper coloring".into());
                }
                if c.num_colors as usize > fg.max_degree() + 1 {
                    return Err(format!(
                        "used {} colors, max_degree {}",
                        c.num_colors,
                        fg.max_degree()
                    ));
                }
            }
            Ok(())
        });
    }
}
