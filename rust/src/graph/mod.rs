//! Dynamic pairwise factor graph over binary variables.
//!
//! The paper's motivating deployment is a *dynamic network*: factors are
//! added and removed on a continuous basis, which makes maintaining a graph
//! coloring (the standard route to parallel Gibbs) expensive. This module
//! provides the mutable substrate: factors live in a slot map so
//! [`FactorId`]s stay stable across removals, and per-variable adjacency is
//! updated in O(degree).
//!
//! Potential convention: a factor stores the strictly positive 2×2 table
//! `P[x1][x2] ∝ p(x_{v1}=x1, x_{v2}=x2)`; each variable additionally
//! carries a unary log-odds `u_v` contributing `exp(u_v · x_v)`.

pub mod coloring;

/// Index of a variable (dense, `0..num_vars`).
pub type VarId = usize;

/// Stable handle of a factor (slot-map key; survives unrelated removals).
pub type FactorId = usize;

/// A pairwise factor: strictly positive 2×2 table over `(v1, v2)`.
#[derive(Clone, Debug, PartialEq)]
pub struct PairFactor {
    /// First endpoint.
    pub v1: VarId,
    /// Second endpoint.
    pub v2: VarId,
    /// `table[x1][x2]`, strictly positive.
    pub table: [[f64; 2]; 2],
}

impl PairFactor {
    /// Build a factor, asserting the table is strictly positive and finite.
    pub fn new(v1: VarId, v2: VarId, table: [[f64; 2]; 2]) -> Self {
        assert!(
            table.iter().flatten().all(|&p| p > 0.0 && p.is_finite()),
            "factor tables must be strictly positive and finite: {table:?}"
        );
        Self { v1, v2, table }
    }

    /// Ising coupling: `exp(+β)` on agreement, `exp(−β)` on disagreement.
    pub fn ising(v1: VarId, v2: VarId, beta: f64) -> Self {
        let hi = beta.exp();
        let lo = (-beta).exp();
        Self::new(v1, v2, [[hi, lo], [lo, hi]])
    }

    /// Log-potential of a joint assignment of the two endpoints.
    #[inline]
    pub fn log_potential(&self, x1: u8, x2: u8) -> f64 {
        self.table[x1 as usize][x2 as usize].ln()
    }
}

/// Dynamic binary pairwise MRF.
#[derive(Clone, Debug, Default)]
pub struct FactorGraph {
    unary: Vec<f64>,
    slots: Vec<Option<PairFactor>>,
    free: Vec<FactorId>,
    /// Per-variable incident factor ids (including removed slots is NOT
    /// allowed: removal cleans adjacency eagerly).
    adj: Vec<Vec<FactorId>>,
    active: usize,
    /// Bumped on every topology mutation; consumers (compiled-artifact
    /// caches, colorings) use it to detect staleness.
    version: u64,
}

impl FactorGraph {
    /// Graph with `n` binary variables, no factors, zero unary fields.
    pub fn new(n: usize) -> Self {
        Self {
            unary: vec![0.0; n],
            slots: Vec::new(),
            free: Vec::new(),
            adj: vec![Vec::new(); n],
            active: 0,
            version: 0,
        }
    }

    /// Number of variables.
    pub fn num_vars(&self) -> usize {
        self.unary.len()
    }

    /// Number of live factors.
    pub fn num_factors(&self) -> usize {
        self.active
    }

    /// Monotone topology version (see struct docs).
    pub fn version(&self) -> u64 {
        self.version
    }

    /// Append a new variable; returns its id.
    pub fn add_var(&mut self, unary_logodds: f64) -> VarId {
        self.unary.push(unary_logodds);
        self.adj.push(Vec::new());
        self.version += 1;
        self.unary.len() - 1
    }

    /// `v`'s unary log-odds.
    pub fn unary(&self, v: VarId) -> f64 {
        self.unary[v]
    }

    /// Overwrite `v`'s unary log-odds (bumps the topology version).
    pub fn set_unary(&mut self, v: VarId, logodds: f64) {
        self.unary[v] = logodds;
        self.version += 1;
    }

    /// Insert a factor; O(1) amortized — the heart of the dynamic story.
    pub fn add_factor(&mut self, f: PairFactor) -> FactorId {
        assert!(f.v1 < self.num_vars() && f.v2 < self.num_vars());
        assert_ne!(f.v1, f.v2, "self-loop factors are not pairwise");
        let (v1, v2) = (f.v1, f.v2);
        let id = match self.free.pop() {
            Some(id) => {
                self.slots[id] = Some(f);
                id
            }
            None => {
                self.slots.push(Some(f));
                self.slots.len() - 1
            }
        };
        self.adj[v1].push(id);
        self.adj[v2].push(id);
        self.active += 1;
        self.version += 1;
        id
    }

    /// Remove a factor by id; O(degree of endpoints).
    pub fn remove_factor(&mut self, id: FactorId) -> Option<PairFactor> {
        let f = self.slots.get_mut(id)?.take()?;
        for v in [f.v1, f.v2] {
            let list = &mut self.adj[v];
            let pos = list.iter().position(|&x| x == id).expect("adjacency desync");
            list.swap_remove(pos);
        }
        self.free.push(id);
        self.active -= 1;
        self.version += 1;
        Some(f)
    }

    /// The live factor in slot `id`, or `None` for dead/unknown slots.
    pub fn factor(&self, id: FactorId) -> Option<&PairFactor> {
        self.slots.get(id).and_then(Option::as_ref)
    }

    /// Iterate live `(id, factor)` pairs in slot order (deterministic).
    pub fn factors(&self) -> impl Iterator<Item = (FactorId, &PairFactor)> {
        self.slots
            .iter()
            .enumerate()
            .filter_map(|(i, s)| s.as_ref().map(|f| (i, f)))
    }

    /// Ids of factors incident to `v`.
    pub fn incident(&self, v: VarId) -> &[FactorId] {
        &self.adj[v]
    }

    /// Number of factors incident to `v`.
    pub fn degree(&self, v: VarId) -> usize {
        self.adj[v].len()
    }

    /// Distinct variable neighbors of `v` (allocates; not for hot loops).
    pub fn neighbors(&self, v: VarId) -> Vec<VarId> {
        let mut out: Vec<VarId> = self.adj[v]
            .iter()
            .map(|&id| {
                let f = self.factor(id).unwrap();
                if f.v1 == v {
                    f.v2
                } else {
                    f.v1
                }
            })
            .collect();
        out.sort_unstable();
        out.dedup();
        out
    }

    /// Unnormalized log-probability of a full assignment (`x[v] ∈ {0, 1}`).
    pub fn log_prob_unnorm(&self, x: &[u8]) -> f64 {
        assert_eq!(x.len(), self.num_vars());
        let mut lp: f64 = x
            .iter()
            .zip(&self.unary)
            .map(|(&xi, &u)| xi as f64 * u)
            .sum();
        for (_, f) in self.factors() {
            lp += self.slots_log_potential(f, x);
        }
        lp
    }

    #[inline]
    fn slots_log_potential(&self, f: &PairFactor, x: &[u8]) -> f64 {
        f.table[x[f.v1] as usize][x[f.v2] as usize].ln()
    }

    /// Conditional log-odds of `x_v = 1` given the rest (sequential Gibbs core).
    #[inline]
    pub fn conditional_logodds(&self, v: VarId, x: &[u8]) -> f64 {
        let mut z = self.unary[v];
        for &id in &self.adj[v] {
            let f = self.slots[id].as_ref().unwrap();
            if f.v1 == v {
                let other = x[f.v2] as usize;
                z += (f.table[1][other] / f.table[0][other]).ln();
            } else {
                let other = x[f.v1] as usize;
                z += (f.table[other][1] / f.table[other][0]).ln();
            }
        }
        z
    }

    /// Maximum variable degree (drives coloring size).
    pub fn max_degree(&self) -> usize {
        (0..self.num_vars()).map(|v| self.degree(v)).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, Gen};

    fn tri() -> (FactorGraph, [FactorId; 3]) {
        let mut g = FactorGraph::new(3);
        let a = g.add_factor(PairFactor::ising(0, 1, 0.5));
        let b = g.add_factor(PairFactor::ising(1, 2, 0.5));
        let c = g.add_factor(PairFactor::ising(0, 2, 0.5));
        (g, [a, b, c])
    }

    #[test]
    fn add_remove_roundtrip() {
        let (mut g, [a, b, c]) = tri();
        assert_eq!(g.num_factors(), 3);
        assert_eq!(g.degree(1), 2);
        let f = g.remove_factor(b).unwrap();
        assert_eq!((f.v1, f.v2), (1, 2));
        assert_eq!(g.num_factors(), 2);
        assert_eq!(g.degree(1), 1);
        assert_eq!(g.remove_factor(b), None); // double remove
        // slot reuse
        let d = g.add_factor(PairFactor::ising(1, 2, 0.9));
        assert_eq!(d, b);
        let _ = (a, c);
    }

    #[test]
    fn version_tracks_mutations() {
        let (mut g, [a, ..]) = tri();
        let v0 = g.version();
        g.remove_factor(a);
        assert!(g.version() > v0);
        let v1 = g.version();
        g.set_unary(0, 1.0);
        assert!(g.version() > v1);
    }

    #[test]
    fn neighbors_and_incident() {
        let (g, _) = tri();
        assert_eq!(g.neighbors(0), vec![1, 2]);
        assert_eq!(g.incident(0).len(), 2);
        assert_eq!(g.max_degree(), 2);
    }

    #[test]
    fn conditional_logodds_matches_definition() {
        let (g, _) = tri();
        // check by brute force: logodds = logP(x_v=1, rest) - logP(x_v=0, rest)
        for pattern in 0..8usize {
            let x: Vec<u8> = (0..3).map(|v| ((pattern >> v) & 1) as u8).collect();
            for v in 0..3 {
                let mut x1 = x.clone();
                x1[v] = 1;
                let mut x0 = x.clone();
                x0[v] = 0;
                let want = g.log_prob_unnorm(&x1) - g.log_prob_unnorm(&x0);
                let got = g.conditional_logodds(v, &x);
                assert!((want - got).abs() < 1e-12, "v={v} pattern={pattern}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "strictly positive")]
    fn rejects_zero_entries() {
        PairFactor::new(0, 1, [[1.0, 0.0], [1.0, 1.0]]);
    }

    #[test]
    #[should_panic(expected = "self-loop")]
    fn rejects_self_loops() {
        let mut g = FactorGraph::new(2);
        g.add_factor(PairFactor::ising(1, 1, 0.1));
    }

    #[test]
    fn prop_random_churn_keeps_adjacency_consistent() {
        check("graph churn consistency", 50, |g: &mut Gen| {
            let n = g.usize_in(2..=12);
            let mut fg = FactorGraph::new(n);
            let mut live: Vec<FactorId> = Vec::new();
            for _ in 0..g.usize_in(1..=60) {
                if live.is_empty() || g.bool() {
                    let v1 = g.usize_in(0..=n - 1);
                    let mut v2 = g.usize_in(0..=n - 1);
                    if v1 == v2 {
                        v2 = (v2 + 1) % n;
                    }
                    let t = g.positive_table(2.0);
                    live.push(fg.add_factor(PairFactor::new(v1, v2, t)));
                } else {
                    let k = g.usize_in(0..=live.len() - 1);
                    let id = live.swap_remove(k);
                    if fg.remove_factor(id).is_none() {
                        return Err(format!("live id {id} missing"));
                    }
                }
            }
            // invariants
            if fg.num_factors() != live.len() {
                return Err("active count desync".into());
            }
            let adj_total: usize = (0..n).map(|v| fg.degree(v)).sum();
            if adj_total != 2 * live.len() {
                return Err("adjacency total != 2F".into());
            }
            for &id in &live {
                let f = fg.factor(id).ok_or("live factor missing")?;
                if !fg.incident(f.v1).contains(&id) || !fg.incident(f.v2).contains(&id) {
                    return Err("incidence lists desync".into());
                }
            }
            Ok(())
        });
    }
}
