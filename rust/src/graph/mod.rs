//! Dynamic pairwise factor graph over discrete variables.
//!
//! The paper's motivating deployment is a *dynamic network*: factors are
//! added and removed on a continuous basis, which makes maintaining a graph
//! coloring (the standard route to parallel Gibbs) expensive. This module
//! provides the mutable substrate: factors live in a slot map so
//! [`FactorId`]s stay stable across removals, and per-variable adjacency is
//! updated in O(degree).
//!
//! Potential convention: a factor stores the strictly positive 2×2 table
//! `P[x1][x2] ∝ p(x_{v1}=x1, x_{v2}=x2)`; each variable additionally
//! carries a unary log-odds `u_v` contributing `exp(u_v · x_v)`.
//!
//! ## K-state (Potts) graphs
//!
//! A graph built with [`FactorGraph::new_k`] holds `K`-state variables
//! (`x_v ∈ 0..K`, `3 ≤ K ≤ 8`). The 2×2 table is then read under the
//! *Potts convention*: `table[0][0]` is the agreement weight and
//! `table[0][1]` the disagreement weight, i.e. the pair potential is
//! `exp(β·1[x1 = x2])` with `β = ln(table[0][0] / table[0][1])` — see
//! [`PairFactor::potts`] / [`PairFactor::potts_beta`]. Unary fields are
//! not defined for K > 2 ([`FactorGraph::set_unary`] rejects nonzero
//! values) and the off-convention table entries are ignored. Binary
//! graphs (`K = 2`, the [`FactorGraph::new`] default) are completely
//! unaffected: every table is read as the general 2×2 potential.

pub mod coloring;

/// Index of a variable (dense, `0..num_vars`).
pub type VarId = usize;

/// Stable handle of a factor (slot-map key; survives unrelated removals).
pub type FactorId = usize;

/// A pairwise factor: strictly positive 2×2 table over `(v1, v2)`.
#[derive(Clone, Debug, PartialEq)]
pub struct PairFactor {
    /// First endpoint.
    pub v1: VarId,
    /// Second endpoint.
    pub v2: VarId,
    /// `table[x1][x2]`, strictly positive.
    pub table: [[f64; 2]; 2],
}

impl PairFactor {
    /// Build a factor, asserting the table is strictly positive and finite.
    pub fn new(v1: VarId, v2: VarId, table: [[f64; 2]; 2]) -> Self {
        assert!(
            table.iter().flatten().all(|&p| p > 0.0 && p.is_finite()),
            "factor tables must be strictly positive and finite: {table:?}"
        );
        Self { v1, v2, table }
    }

    /// Ising coupling: `exp(+β)` on agreement, `exp(−β)` on disagreement.
    pub fn ising(v1: VarId, v2: VarId, beta: f64) -> Self {
        let hi = beta.exp();
        let lo = (-beta).exp();
        Self::new(v1, v2, [[hi, lo], [lo, hi]])
    }

    /// Potts coupling for K-state graphs: `exp(β)` on agreement, `1`
    /// otherwise, stored under the Potts table convention (module docs).
    /// On a binary graph this is just a rescaled Ising table, so the same
    /// constructor serves both.
    pub fn potts(v1: VarId, v2: VarId, beta: f64) -> Self {
        Self::new(v1, v2, [[beta.exp(), 1.0], [1.0, beta.exp()]])
    }

    /// The Potts coupling this table encodes:
    /// `β = ln(table[0][0] / table[0][1])` (agreement vs disagreement
    /// weight — exact for [`PairFactor::potts`] and
    /// [`PairFactor::ising`]-built tables, where it reads `2β_ising`).
    #[inline]
    pub fn potts_beta(&self) -> f64 {
        (self.table[0][0] / self.table[0][1]).ln()
    }

    /// Log-potential of a joint assignment of the two endpoints.
    #[inline]
    pub fn log_potential(&self, x1: u8, x2: u8) -> f64 {
        self.table[x1 as usize][x2 as usize].ln()
    }

    /// Log-potential under the K-state Potts convention: `β·1[x1 = x2]`
    /// plus the constant `ln(table[0][1])` (so K = 2 Potts tables agree
    /// with [`PairFactor::log_potential`] on agree/disagree pairs).
    #[inline]
    pub fn log_potential_potts(&self, x1: u8, x2: u8) -> f64 {
        if x1 == x2 {
            self.table[0][0].ln()
        } else {
            self.table[0][1].ln()
        }
    }
}

/// Largest variable cardinality a graph may carry (3 bit-planes in the
/// lane engine's packed state).
pub const MAX_STATES: usize = 8;

/// Dynamic discrete pairwise MRF (binary by default; see module docs for
/// the K-state Potts convention).
#[derive(Clone, Debug)]
pub struct FactorGraph {
    unary: Vec<f64>,
    slots: Vec<Option<PairFactor>>,
    free: Vec<FactorId>,
    /// Per-variable incident factor ids (including removed slots is NOT
    /// allowed: removal cleans adjacency eagerly).
    adj: Vec<Vec<FactorId>>,
    active: usize,
    /// Bumped on every topology mutation; consumers (compiled-artifact
    /// caches, colorings) use it to detect staleness.
    version: u64,
    /// States per variable (2 = binary, the general-table convention).
    k: usize,
}

impl Default for FactorGraph {
    fn default() -> Self {
        Self::new(0)
    }
}

impl FactorGraph {
    /// Graph with `n` binary variables, no factors, zero unary fields.
    pub fn new(n: usize) -> Self {
        Self::new_k(n, 2)
    }

    /// Graph with `n` `k`-state variables (`2 ≤ k ≤ 8`). For `k > 2`
    /// every factor is read under the Potts convention and unary fields
    /// must stay zero.
    pub fn new_k(n: usize, k: usize) -> Self {
        assert!(
            (2..=MAX_STATES).contains(&k),
            "variable cardinality must be 2..={MAX_STATES}, got {k}"
        );
        Self {
            unary: vec![0.0; n],
            slots: Vec::new(),
            free: Vec::new(),
            adj: vec![Vec::new(); n],
            active: 0,
            version: 0,
            k,
        }
    }

    /// States per variable (2 = binary).
    pub fn k(&self) -> usize {
        self.k
    }

    /// Number of variables.
    pub fn num_vars(&self) -> usize {
        self.unary.len()
    }

    /// Number of live factors.
    pub fn num_factors(&self) -> usize {
        self.active
    }

    /// Monotone topology version (see struct docs).
    pub fn version(&self) -> u64 {
        self.version
    }

    /// Append a new variable; returns its id.
    pub fn add_var(&mut self, unary_logodds: f64) -> VarId {
        self.unary.push(unary_logodds);
        self.adj.push(Vec::new());
        self.version += 1;
        self.unary.len() - 1
    }

    /// `v`'s unary log-odds.
    pub fn unary(&self, v: VarId) -> f64 {
        self.unary[v]
    }

    /// Overwrite `v`'s unary log-odds (bumps the topology version).
    /// Unary fields are a binary-variable concept; K-state graphs reject
    /// nonzero values loudly rather than silently sampling a different
    /// model.
    pub fn set_unary(&mut self, v: VarId, logodds: f64) {
        assert!(
            self.k == 2 || logodds == 0.0,
            "unary fields are not defined for k={} graphs",
            self.k
        );
        self.unary[v] = logodds;
        self.version += 1;
    }

    /// Insert a factor; O(1) amortized — the heart of the dynamic story.
    pub fn add_factor(&mut self, f: PairFactor) -> FactorId {
        assert!(f.v1 < self.num_vars() && f.v2 < self.num_vars());
        assert_ne!(f.v1, f.v2, "self-loop factors are not pairwise");
        let (v1, v2) = (f.v1, f.v2);
        let id = match self.free.pop() {
            Some(id) => {
                self.slots[id] = Some(f);
                id
            }
            None => {
                self.slots.push(Some(f));
                self.slots.len() - 1
            }
        };
        self.adj[v1].push(id);
        self.adj[v2].push(id);
        self.active += 1;
        self.version += 1;
        id
    }

    /// Remove a factor by id; O(degree of endpoints).
    pub fn remove_factor(&mut self, id: FactorId) -> Option<PairFactor> {
        let f = self.slots.get_mut(id)?.take()?;
        for v in [f.v1, f.v2] {
            let list = &mut self.adj[v];
            let pos = list.iter().position(|&x| x == id).expect("adjacency desync");
            list.swap_remove(pos);
        }
        self.free.push(id);
        self.active -= 1;
        self.version += 1;
        Some(f)
    }

    /// The live factor in slot `id`, or `None` for dead/unknown slots.
    pub fn factor(&self, id: FactorId) -> Option<&PairFactor> {
        self.slots.get(id).and_then(Option::as_ref)
    }

    /// Iterate live `(id, factor)` pairs in slot order (deterministic).
    pub fn factors(&self) -> impl Iterator<Item = (FactorId, &PairFactor)> {
        self.slots
            .iter()
            .enumerate()
            .filter_map(|(i, s)| s.as_ref().map(|f| (i, f)))
    }

    /// Ids of factors incident to `v`.
    pub fn incident(&self, v: VarId) -> &[FactorId] {
        &self.adj[v]
    }

    /// Number of factors incident to `v`.
    pub fn degree(&self, v: VarId) -> usize {
        self.adj[v].len()
    }

    /// Distinct variable neighbors of `v` (allocates; not for hot loops).
    pub fn neighbors(&self, v: VarId) -> Vec<VarId> {
        let mut out: Vec<VarId> = self.adj[v]
            .iter()
            .map(|&id| {
                let f = self.factor(id).unwrap();
                if f.v1 == v {
                    f.v2
                } else {
                    f.v1
                }
            })
            .collect();
        out.sort_unstable();
        out.dedup();
        out
    }

    /// Unnormalized log-probability of a full assignment (`x[v] ∈ 0..k`).
    /// Binary graphs use the general 2×2 table + unary convention; K > 2
    /// graphs score every factor under the Potts convention (module docs).
    pub fn log_prob_unnorm(&self, x: &[u8]) -> f64 {
        assert_eq!(x.len(), self.num_vars());
        if self.k > 2 {
            debug_assert!(x.iter().all(|&xi| (xi as usize) < self.k));
            return self
                .factors()
                .map(|(_, f)| f.log_potential_potts(x[f.v1], x[f.v2]))
                .sum();
        }
        let mut lp: f64 = x
            .iter()
            .zip(&self.unary)
            .map(|(&xi, &u)| xi as f64 * u)
            .sum();
        for (_, f) in self.factors() {
            lp += self.slots_log_potential(f, x);
        }
        lp
    }

    #[inline]
    fn slots_log_potential(&self, f: &PairFactor, x: &[u8]) -> f64 {
        f.table[x[f.v1] as usize][x[f.v2] as usize].ln()
    }

    /// Conditional log-odds of `x_v = 1` given the rest (sequential Gibbs core).
    #[inline]
    pub fn conditional_logodds(&self, v: VarId, x: &[u8]) -> f64 {
        let mut z = self.unary[v];
        for &id in &self.adj[v] {
            let f = self.slots[id].as_ref().unwrap();
            if f.v1 == v {
                let other = x[f.v2] as usize;
                z += (f.table[1][other] / f.table[0][other]).ln();
            } else {
                let other = x[f.v1] as usize;
                z += (f.table[other][1] / f.table[other][0]).ln();
            }
        }
        z
    }

    /// Conditional log-scores of `x_v = s` for `s ∈ 0..k` given the rest,
    /// written into `scores` (K-state sequential Gibbs core). Under the
    /// Potts convention each incident factor contributes `β·1[x_other = s]`,
    /// so we accumulate `β_f` onto the neighbor's current state only.
    /// Valid for any `k ≥ 2`; on binary graphs it matches
    /// [`FactorGraph::conditional_logodds`] up to the shared constant.
    pub fn conditional_scores_k(&self, v: VarId, x: &[u8], scores: &mut [f64]) {
        assert_eq!(scores.len(), self.k);
        scores.fill(0.0);
        if self.k == 2 {
            scores[1] = self.conditional_logodds(v, x);
            return;
        }
        for &id in &self.adj[v] {
            let f = self.slots[id].as_ref().unwrap();
            let other = if f.v1 == v { x[f.v2] } else { x[f.v1] };
            scores[other as usize] += f.potts_beta();
        }
    }

    /// Maximum variable degree (drives coloring size).
    pub fn max_degree(&self) -> usize {
        (0..self.num_vars()).map(|v| self.degree(v)).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, Gen};

    fn tri() -> (FactorGraph, [FactorId; 3]) {
        let mut g = FactorGraph::new(3);
        let a = g.add_factor(PairFactor::ising(0, 1, 0.5));
        let b = g.add_factor(PairFactor::ising(1, 2, 0.5));
        let c = g.add_factor(PairFactor::ising(0, 2, 0.5));
        (g, [a, b, c])
    }

    #[test]
    fn add_remove_roundtrip() {
        let (mut g, [a, b, c]) = tri();
        assert_eq!(g.num_factors(), 3);
        assert_eq!(g.degree(1), 2);
        let f = g.remove_factor(b).unwrap();
        assert_eq!((f.v1, f.v2), (1, 2));
        assert_eq!(g.num_factors(), 2);
        assert_eq!(g.degree(1), 1);
        assert_eq!(g.remove_factor(b), None); // double remove
        // slot reuse
        let d = g.add_factor(PairFactor::ising(1, 2, 0.9));
        assert_eq!(d, b);
        let _ = (a, c);
    }

    #[test]
    fn version_tracks_mutations() {
        let (mut g, [a, ..]) = tri();
        let v0 = g.version();
        g.remove_factor(a);
        assert!(g.version() > v0);
        let v1 = g.version();
        g.set_unary(0, 1.0);
        assert!(g.version() > v1);
    }

    #[test]
    fn neighbors_and_incident() {
        let (g, _) = tri();
        assert_eq!(g.neighbors(0), vec![1, 2]);
        assert_eq!(g.incident(0).len(), 2);
        assert_eq!(g.max_degree(), 2);
    }

    #[test]
    fn conditional_logodds_matches_definition() {
        let (g, _) = tri();
        // check by brute force: logodds = logP(x_v=1, rest) - logP(x_v=0, rest)
        for pattern in 0..8usize {
            let x: Vec<u8> = (0..3).map(|v| ((pattern >> v) & 1) as u8).collect();
            for v in 0..3 {
                let mut x1 = x.clone();
                x1[v] = 1;
                let mut x0 = x.clone();
                x0[v] = 0;
                let want = g.log_prob_unnorm(&x1) - g.log_prob_unnorm(&x0);
                let got = g.conditional_logodds(v, &x);
                assert!((want - got).abs() < 1e-12, "v={v} pattern={pattern}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "strictly positive")]
    fn rejects_zero_entries() {
        PairFactor::new(0, 1, [[1.0, 0.0], [1.0, 1.0]]);
    }

    #[test]
    #[should_panic(expected = "self-loop")]
    fn rejects_self_loops() {
        let mut g = FactorGraph::new(2);
        g.add_factor(PairFactor::ising(1, 1, 0.1));
    }

    #[test]
    fn potts_beta_roundtrips() {
        let f = PairFactor::potts(0, 1, 0.7);
        assert!((f.potts_beta() - 0.7).abs() < 1e-12);
        // Ising tables read as 2β under the Potts convention.
        let f = PairFactor::ising(0, 1, 0.3);
        assert!((f.potts_beta() - 0.6).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "variable cardinality")]
    fn rejects_k_above_max() {
        FactorGraph::new_k(2, 9);
    }

    #[test]
    #[should_panic(expected = "unary fields are not defined")]
    fn kstate_rejects_nonzero_unary() {
        let mut g = FactorGraph::new_k(2, 3);
        g.set_unary(0, 0.5);
    }

    #[test]
    fn kstate_log_prob_matches_manual_potts_sum() {
        let mut g = FactorGraph::new_k(3, 3);
        g.add_factor(PairFactor::potts(0, 1, 0.4));
        g.add_factor(PairFactor::potts(1, 2, 0.9));
        for code in 0..27usize {
            let x = [(code % 3) as u8, ((code / 3) % 3) as u8, ((code / 9) % 3) as u8];
            let want = 0.4 * f64::from(x[0] == x[1]) + 0.9 * f64::from(x[1] == x[2]);
            assert!((g.log_prob_unnorm(&x) - want).abs() < 1e-12, "code={code}");
        }
    }

    #[test]
    fn conditional_scores_k_matches_log_prob_differences() {
        let mut g = FactorGraph::new_k(3, 3);
        g.add_factor(PairFactor::potts(0, 1, 0.4));
        g.add_factor(PairFactor::potts(1, 2, 0.9));
        g.add_factor(PairFactor::potts(0, 2, -0.3));
        let mut scores = vec![0.0; 3];
        for code in 0..27usize {
            let x = [(code % 3) as u8, ((code / 3) % 3) as u8, ((code / 9) % 3) as u8];
            for v in 0..3 {
                g.conditional_scores_k(v, &x, &mut scores);
                for s in 0..3u8 {
                    let mut xs = x;
                    xs[v] = s;
                    let mut x0 = x;
                    x0[v] = 0;
                    let want = g.log_prob_unnorm(&xs) - g.log_prob_unnorm(&x0);
                    let got = scores[s as usize] - scores[0];
                    assert!((want - got).abs() < 1e-12, "v={v} s={s} code={code}");
                }
            }
        }
    }

    #[test]
    fn binary_conditional_scores_k_matches_logodds() {
        let (mut g, _) = tri();
        g.set_unary(1, -0.4);
        let mut scores = vec![0.0; 2];
        for pattern in 0..8usize {
            let x: Vec<u8> = (0..3).map(|v| ((pattern >> v) & 1) as u8).collect();
            for v in 0..3 {
                g.conditional_scores_k(v, &x, &mut scores);
                let want = g.conditional_logodds(v, &x);
                assert!((scores[1] - scores[0] - want).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn prop_random_churn_keeps_adjacency_consistent() {
        check("graph churn consistency", 50, |g: &mut Gen| {
            let n = g.usize_in(2..=12);
            let mut fg = FactorGraph::new(n);
            let mut live: Vec<FactorId> = Vec::new();
            for _ in 0..g.usize_in(1..=60) {
                if live.is_empty() || g.bool() {
                    let v1 = g.usize_in(0..=n - 1);
                    let mut v2 = g.usize_in(0..=n - 1);
                    if v1 == v2 {
                        v2 = (v2 + 1) % n;
                    }
                    let t = g.positive_table(2.0);
                    live.push(fg.add_factor(PairFactor::new(v1, v2, t)));
                } else {
                    let k = g.usize_in(0..=live.len() - 1);
                    let id = live.swap_remove(k);
                    if fg.remove_factor(id).is_none() {
                        return Err(format!("live id {id} missing"));
                    }
                }
            }
            // invariants
            if fg.num_factors() != live.len() {
                return Err("active count desync".into());
            }
            let adj_total: usize = (0..n).map(|v| fg.degree(v)).sum();
            if adj_total != 2 * live.len() {
                return Err("adjacency total != 2F".into());
            }
            for &id in &live {
                let f = fg.factor(id).ok_or("live factor missing")?;
                if !fg.incident(f.v1).contains(&id) || !fg.incident(f.v2).contains(&id) {
                    return Err("incidence lists desync".into());
                }
            }
            Ok(())
        });
    }
}
