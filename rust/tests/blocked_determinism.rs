//! Block-plan determinism: the adaptive blocking subsystem must be a
//! pure function of (topology, trajectory, policy) — never of how the
//! host is sized or of the order churn ops happened to arrive in.
//!
//! Two contracts:
//!
//! * **Placement invariance** — blocked tenants produce bit-identical
//!   marginals and identical plan summaries across shard counts {1, 4} ×
//!   pool sizes {0, 4}. The plan is re-derived from agreement EWMAs that
//!   are themselves deterministic functions of the (placement-invariant)
//!   trajectory, so any divergence here means a worker observed the plan
//!   mid-rebuild or the stats were accumulated in pool-dependent order.
//! * **Op-order invariance** — two churn batches that net to the same
//!   graph yield the same canonical plan, even though the batches assign
//!   different factor slots. Candidate edges are ordered by (strength,
//!   endpoints) with the slot id only as a final tiebreaker, and recycled
//!   slots restart at the neutral EWMA, so the plan cannot depend on
//!   slot-assignment history.

use pdgibbs::coordinator::{Coordinator, CoordinatorConfig, TenantConfig, TenantStats};
use pdgibbs::duality::BlockPolicy;
use pdgibbs::engine::{EngineConfig, KernelKind, LanePdSampler, SweepPolicy};
use pdgibbs::graph::{FactorGraph, PairFactor};
use pdgibbs::workloads::{self, ChurnOp};

fn blocked(cap: usize, epoch: usize) -> SweepPolicy {
    SweepPolicy::Blocked(BlockPolicy { cap, epoch })
}

/// Run one blocked tenant (strongly-coupled grid + mid-run churn) on a
/// coordinator of the given shape; return its marginals and stats.
fn serve(shards: usize, pool_threads: usize) -> (Vec<f64>, TenantStats) {
    let mut coord = Coordinator::spawn(CoordinatorConfig {
        shards,
        pool_threads,
        quantum: 0, // request-driven: sweep counts are exact
        ..Default::default()
    });
    let client = coord.client();
    let g = workloads::ising_grid(3, 3, 0.8, 0.05);
    client
        .create_tenant(
            7,
            g,
            TenantConfig {
                chains: 64,
                seed: 0xB10C,
                sweep: blocked(4, 8),
                ..TenantConfig::default()
            },
        )
        .unwrap();
    client.sweep(7, 60).unwrap();
    // churn mid-run: drop a live factor, add a strong cross edge
    client
        .apply(
            7,
            vec![
                ChurnOp::RemoveLive { index: 2 },
                ChurnOp::Add { v1: 0, v2: 4, beta: 0.8 },
            ],
        )
        .unwrap();
    client.sweep(7, 60).unwrap();
    let m = client.marginals(7).unwrap();
    let stats = client.stats(7).unwrap();
    coord.shutdown();
    (m, stats)
}

#[test]
fn blocked_tenants_are_identical_across_shard_counts_and_pool_sizes() {
    let (m_ref, s_ref) = serve(1, 0);
    assert!(s_ref.blocks >= 1, "β=0.8 grid must grow blocks");
    assert_eq!(s_ref.sweeps_done, 120);
    for (shards, pool) in [(1usize, 4usize), (4, 0), (4, 4)] {
        let (m, s) = serve(shards, pool);
        assert_eq!(
            m, m_ref,
            "shards={shards} pool={pool}: placement changed the trajectory"
        );
        assert_eq!(
            (s.blocks, s.blocked_vars, s.tree_slots),
            (s_ref.blocks, s_ref.blocked_vars, s_ref.tree_slots),
            "shards={shards} pool={pool}: placement changed the plan"
        );
        assert_eq!(s.cost, s_ref.cost, "plan repricing must match too");
    }
}

/// A 6-variable strongly-coupled ring — every edge qualifies, so the
/// planner has real choices to make and op-order bugs have room to show.
fn ring6(beta: f64) -> FactorGraph {
    let mut g = FactorGraph::new(6);
    for v in 0..6 {
        g.set_unary(v, 0.05);
        g.add_factor(PairFactor::ising(v, (v + 1) % 6, beta));
    }
    g
}

/// Apply `ops` (lockstep graph + engine), with no sweeps interleaved.
fn apply_ops(g: &mut FactorGraph, eng: &mut LanePdSampler, ops: &[(bool, usize, usize)]) {
    for &(add, a, b) in ops {
        if add {
            let id = g.add_factor(PairFactor::ising(a, b, 0.8));
            eng.add_factor(id, g.factor(id).unwrap());
        } else {
            // remove the live factor joining (a, b)
            let id = g
                .factors()
                .find(|(_, f)| (f.v1.min(f.v2), f.v1.max(f.v2)) == (a.min(b), a.max(b)))
                .map(|(id, _)| id)
                .expect("edge to remove");
            g.remove_factor(id).unwrap();
            assert!(eng.remove_factor(id));
        }
    }
}

#[test]
fn churn_batches_netting_the_same_graph_yield_the_same_canonical_plan() {
    // both engines run the same warmup, then receive churn batches that
    // net to the same topology but in different op orders — so the added
    // factors land in different slots. The next sweep's plan must be
    // canonically equal (same var sets, same tree edges by endpoints).
    let cfg = EngineConfig {
        lanes: 64,
        seed: 0x0D0A,
        kernel: KernelKind::default(),
        // epoch 8 lets warmup plans form; the post-churn re-plan is
        // triggered eagerly by staleness, not by the epoch boundary
        sweep: blocked(3, 8),
    };
    let mut ga = ring6(0.8);
    let mut gb = ring6(0.8);
    let mut a = LanePdSampler::with_config(&ga, cfg);
    let mut b = LanePdSampler::with_config(&gb, cfg);
    for _ in 0..48 {
        a.sweep();
        b.sweep();
    }
    assert_eq!(a.state_words(), b.state_words(), "warmup must be identical");
    let plan_a = a.block_plan().expect("plan formed").canonical();
    assert_eq!(plan_a, b.block_plan().expect("plan formed").canonical());
    assert!(!plan_a.is_empty(), "ring must have grown blocks");
    // net effect for both: remove ring edges (0,1) and (3,4), add chords
    // (0,3) and (1,4) — but in different orders
    apply_ops(&mut ga, &mut a, &[
        (false, 0, 1),
        (true, 0, 3),
        (false, 3, 4),
        (true, 1, 4),
    ]);
    apply_ops(&mut gb, &mut b, &[
        (true, 1, 4),
        (false, 3, 4),
        (true, 0, 3),
        (false, 0, 1),
    ]);
    a.sweep();
    b.sweep();
    assert_eq!(
        a.block_plan().unwrap().canonical(),
        b.block_plan().unwrap().canonical(),
        "op order leaked into the plan"
    );
    // the surviving ring edges kept their agreement stats, so the
    // post-churn plan still blocks something immediately
    assert!(a.block_summary().0 >= 1);
}

#[test]
fn clamp_mid_epoch_is_an_eager_order_invariant_plan_mutation() {
    // Clamping evidence is a semantic mutation on par with factor churn:
    // the very next sweep must run a fresh plan that excludes the clamped
    // site, even strictly inside an epoch window, and the plan must not
    // depend on whether the clamp landed before or after concurrent churn.
    let cfg = EngineConfig {
        lanes: 64,
        seed: 0xC1A3,
        kernel: KernelKind::default(),
        sweep: blocked(3, 8),
    };
    let mut ga = ring6(0.9);
    let mut gb = ring6(0.9);
    let mut a = LanePdSampler::with_config(&ga, cfg);
    let mut b = LanePdSampler::with_config(&gb, cfg);
    // 45 sweeps with epoch 8 stops three short of the next boundary, so
    // every re-plan observed below is eager, not epoch-driven
    for _ in 0..45 {
        a.sweep();
        b.sweep();
    }
    let plan = a.block_plan().expect("warmup plan").clone();
    assert_eq!(plan.canonical(), b.block_plan().expect("warmup plan").canonical());
    let victim = plan.blocks[0].nodes[0].v as usize;
    // same net mutation, opposite interleavings: clamp-then-churn vs
    // churn-then-clamp
    a.clamp(victim, 1).unwrap();
    apply_ops(&mut ga, &mut a, &[(true, 0, 3)]);
    apply_ops(&mut gb, &mut b, &[(true, 0, 3)]);
    b.clamp(victim, 1).unwrap();
    a.sweep(); // sweep 46: strictly mid-epoch for both engines
    b.sweep();
    assert_eq!(a.state_words(), b.state_words(), "interleaving changed the trajectory");
    let pa = a.block_plan().expect("eager re-plan").canonical();
    assert_eq!(
        pa,
        b.block_plan().expect("eager re-plan").canonical(),
        "clamp/churn interleaving leaked into the plan"
    );
    assert!(
        a.block_plan()
            .unwrap()
            .blocks
            .iter()
            .all(|blk| blk.nodes.iter().all(|n| n.v as usize != victim)),
        "clamped site survived an in-epoch re-plan"
    );
    // releasing the evidence is the same kind of mutation: the re-plan is
    // eager again, and the site restarts from neutral EWMAs rather than
    // inheriting its pre-clamp agreement history
    a.unclamp(victim).unwrap();
    b.unclamp(victim).unwrap();
    a.sweep();
    b.sweep();
    assert_eq!(
        a.block_plan().unwrap().canonical(),
        b.block_plan().unwrap().canonical()
    );
    assert!(
        a.block_plan()
            .unwrap()
            .blocks
            .iter()
            .all(|blk| blk.nodes.iter().all(|n| n.v as usize != victim)),
        "released site must re-earn membership from neutral EWMAs"
    );
    // after a couple of epoch boundaries the β=0.9 coupling pulls the
    // released site's agreement back above threshold: the plan keeps
    // blocking, and every var (victim included) is once again a planner
    // candidate — sweeps stay well-defined either way
    for _ in 0..24 {
        a.sweep();
    }
    assert!(a.block_summary().0 >= 1, "plan must keep blocking after release");
    assert_eq!(a.clamped_count(), 0);
}
