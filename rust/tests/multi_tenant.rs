//! End-to-end multi-tenant coordinator tests over the public API:
//! a seeded arrival/departure traffic trace replayed against a sharded
//! coordinator, with per-tenant correctness checked against exact
//! enumeration and shard-count invariance of the final answers.

use pdgibbs::coordinator::{Coordinator, CoordinatorConfig, TenantConfig};
use pdgibbs::graph::FactorGraph;
use pdgibbs::inference::exact;
use pdgibbs::workloads::{ChurnTrace, TenantEvent, TenantTrace, TenantTraceConfig};

fn tenant_config(seed: u64) -> TenantConfig {
    TenantConfig {
        chains: 8,
        seed,
        monitor_vars: Vec::new(),
    }
}

/// Replay a traffic trace (request-driven, background off) and return
/// `(tenant, marginals, reference graph)` for every survivor.
fn replay(shards: usize, trace: &TenantTrace) -> Vec<(u64, Vec<f64>, FactorGraph)> {
    let mut coord = Coordinator::spawn(CoordinatorConfig {
        shards,
        quantum: 0,
        ..Default::default()
    });
    let client = coord.client();
    // local mirror of every tenant's expected graph
    let mut mirror: std::collections::HashMap<u64, (FactorGraph, Vec<usize>)> =
        std::collections::HashMap::new();
    for event in &trace.events {
        match event {
            TenantEvent::Create { tenant, vars, seed } => {
                client
                    .create_tenant(*tenant, FactorGraph::new(*vars), tenant_config(*seed))
                    .unwrap();
                mirror.insert(*tenant, (FactorGraph::new(*vars), Vec::new()));
            }
            TenantEvent::Apply { tenant, ops } => {
                client.apply(*tenant, ops.clone()).unwrap();
                let (g, live) = mirror.get_mut(tenant).unwrap();
                for op in ops {
                    ChurnTrace::apply(g, live, op);
                }
            }
            TenantEvent::Sweep { tenant, n } => client.sweep(*tenant, *n).unwrap(),
            TenantEvent::Drop { tenant } => {
                assert!(client.drop_tenant(*tenant).unwrap());
                mirror.remove(tenant);
            }
        }
    }
    // settle every survivor, then read marginals
    let mut survivors: Vec<u64> = mirror.keys().copied().collect();
    survivors.sort_unstable();
    for &t in &survivors {
        client.sweep(t, 300).unwrap();
        client.reset_stats(t).unwrap();
        client.sweep(t, 5000).unwrap();
    }
    let out = survivors
        .into_iter()
        .map(|t| {
            let m = client.marginals(t).unwrap();
            let (g, _) = mirror.remove(&t).unwrap();
            (t, m, g)
        })
        .collect();
    coord.shutdown();
    out
}

#[test]
fn traffic_trace_marginals_match_exact_and_shard_count_is_irrelevant() {
    let trace = TenantTrace::generate(
        TenantTraceConfig {
            max_tenants: 8,
            steps: 120,
            vars: (4, 8),
            target_factors: 7,
            ops_per_apply: 3,
            sweeps_per_step: 4,
            beta_max: 0.5,
        },
        0xFACADE,
    );
    let on_one = replay(1, &trace);
    let on_three = replay(3, &trace);
    assert!(!on_one.is_empty(), "trace must leave survivors");
    assert_eq!(on_one.len(), on_three.len());
    for ((t1, m1, g), (t3, m3, _)) in on_one.iter().zip(&on_three) {
        assert_eq!(t1, t3);
        assert_eq!(m1, m3, "tenant {t1}: shard count changed the trajectory");
        let want = exact::enumerate(g).marginals;
        for v in 0..g.num_vars() {
            assert!(
                (m1[v] - want[v]).abs() < 0.02,
                "tenant {t1} v={v}: {} vs exact {}",
                m1[v],
                want[v]
            );
        }
    }
}

#[test]
fn suspended_tenants_survive_heavy_neighbors() {
    // a suspended tenant keeps its graph and answers stats while a big
    // neighbor churns and sweeps on the same coordinator
    let mut coord = Coordinator::spawn(CoordinatorConfig {
        shards: 2,
        quantum: 4096,
        ..Default::default()
    });
    let client = coord.client();
    client
        .create_tenant(
            10,
            pdgibbs::workloads::ising_grid(2, 2, 0.2, 0.0),
            tenant_config(1),
        )
        .unwrap();
    client
        .create_tenant(
            11,
            pdgibbs::workloads::ising_grid(12, 12, 0.25, 0.0),
            tenant_config(2),
        )
        .unwrap();
    client.suspend(10).unwrap();
    client.sweep(11, 500).unwrap();
    let s10 = client.stats(10).unwrap();
    assert!(s10.suspended);
    assert_eq!(s10.num_vars, 4);
    client.resume(10).unwrap();
    client.sweep(10, 200).unwrap();
    client.reset_stats(10).unwrap();
    client.sweep(10, 2000).unwrap();
    let m = client.marginals(10).unwrap();
    assert_eq!(m.len(), 4);
    assert!(m.iter().all(|p| (0.05..=0.95).contains(p)));
    coord.shutdown();
}
