//! End-to-end multi-tenant coordinator tests over the public API:
//! a seeded arrival/departure traffic trace replayed against a sharded
//! coordinator, with per-tenant correctness checked against exact
//! enumeration and shard-count invariance of the final answers.

use std::sync::Arc;

use pdgibbs::coordinator::{Coordinator, CoordinatorConfig, TenantConfig};
use pdgibbs::engine::{KernelKind, LanePdSampler};
use pdgibbs::graph::{FactorGraph, PairFactor};
use pdgibbs::inference::exact;
use pdgibbs::util::proptest::{check, Gen};
use pdgibbs::util::ThreadPool;
use pdgibbs::workloads::{ChurnOp, ChurnTrace, TenantEvent, TenantTrace, TenantTraceConfig};

fn tenant_config(seed: u64) -> TenantConfig {
    TenantConfig {
        chains: 8,
        seed,
        ..TenantConfig::default()
    }
}

/// Replay a traffic trace (request-driven, background off) and return
/// `(tenant, marginals, reference graph)` for every survivor.
fn replay(shards: usize, trace: &TenantTrace) -> Vec<(u64, Vec<f64>, FactorGraph)> {
    let mut coord = Coordinator::spawn(CoordinatorConfig {
        shards,
        quantum: 0,
        ..Default::default()
    });
    let client = coord.client();
    // local mirror of every tenant's expected graph
    let mut mirror: std::collections::HashMap<u64, (FactorGraph, Vec<usize>)> =
        std::collections::HashMap::new();
    for event in &trace.events {
        match event {
            TenantEvent::Create { tenant, vars, seed } => {
                client
                    .create_tenant(*tenant, FactorGraph::new(*vars), tenant_config(*seed))
                    .unwrap();
                mirror.insert(*tenant, (FactorGraph::new(*vars), Vec::new()));
            }
            TenantEvent::Apply { tenant, ops } => {
                client.apply(*tenant, ops.clone()).unwrap();
                let (g, live) = mirror.get_mut(tenant).unwrap();
                for op in ops {
                    ChurnTrace::apply(g, live, op);
                }
            }
            TenantEvent::Sweep { tenant, n } => client.sweep(*tenant, *n).unwrap(),
            TenantEvent::Drop { tenant } => {
                assert!(client.drop_tenant(*tenant).unwrap());
                mirror.remove(tenant);
            }
        }
    }
    // settle every survivor, then read marginals
    let mut survivors: Vec<u64> = mirror.keys().copied().collect();
    survivors.sort_unstable();
    for &t in &survivors {
        client.sweep(t, 300).unwrap();
        client.reset_stats(t).unwrap();
        client.sweep(t, 5000).unwrap();
    }
    let out = survivors
        .into_iter()
        .map(|t| {
            let m = client.marginals(t).unwrap();
            let (g, _) = mirror.remove(&t).unwrap();
            (t, m, g)
        })
        .collect();
    coord.shutdown();
    out
}

#[test]
fn traffic_trace_marginals_match_exact_and_shard_count_is_irrelevant() {
    let trace = TenantTrace::generate(
        TenantTraceConfig {
            max_tenants: 8,
            steps: 120,
            vars: (4, 8),
            target_factors: 7,
            ops_per_apply: 3,
            sweeps_per_step: 4,
            beta_max: 0.5,
        },
        0xFACADE,
    );
    let on_one = replay(1, &trace);
    let on_three = replay(3, &trace);
    assert!(!on_one.is_empty(), "trace must leave survivors");
    assert_eq!(on_one.len(), on_three.len());
    for ((t1, m1, g), (t3, m3, _)) in on_one.iter().zip(&on_three) {
        assert_eq!(t1, t3);
        assert_eq!(m1, m3, "tenant {t1}: shard count changed the trajectory");
        let want = exact::enumerate(g).marginals;
        for v in 0..g.num_vars() {
            assert!(
                (m1[v] - want[v]).abs() < 0.02,
                "tenant {t1} v={v}: {} vs exact {}",
                m1[v],
                want[v]
            );
        }
    }
}

#[test]
fn suspended_tenants_survive_heavy_neighbors() {
    // a suspended tenant keeps its graph and answers stats while a big
    // neighbor churns and sweeps on the same coordinator
    let mut coord = Coordinator::spawn(CoordinatorConfig {
        shards: 2,
        quantum: 4096,
        ..Default::default()
    });
    let client = coord.client();
    client
        .create_tenant(
            10,
            pdgibbs::workloads::ising_grid(2, 2, 0.2, 0.0),
            tenant_config(1),
        )
        .unwrap();
    client
        .create_tenant(
            11,
            pdgibbs::workloads::ising_grid(12, 12, 0.25, 0.0),
            tenant_config(2),
        )
        .unwrap();
    client.suspend(10).unwrap();
    client.sweep(11, 500).unwrap();
    let s10 = client.stats(10).unwrap();
    assert!(s10.suspended);
    assert_eq!(s10.num_vars, 4);
    client.resume(10).unwrap();
    client.sweep(10, 200).unwrap();
    client.reset_stats(10).unwrap();
    client.sweep(10, 2000).unwrap();
    let m = client.marginals(10).unwrap();
    assert_eq!(m.len(), 4);
    assert!(m.iter().all(|p| (0.05..=0.95).contains(p)));
    coord.shutdown();
}

#[test]
fn suspend_churn_resume_answers_fresh_marginals_not_the_parked_snapshot() {
    // lifecycle edge case: a tenant suspended mid-serving receives churn
    // while parked. The churn shifts its target distribution, so after
    // resume the tenant must answer marginals of the NEW topology — not
    // keep serving the pre-suspension snapshot (which `park()`
    // deliberately preserves for the no-churn case).
    let mut coord = Coordinator::spawn(CoordinatorConfig {
        shards: 2,
        quantum: 0, // request-driven: deterministic
        ..Default::default()
    });
    let client = coord.client();
    let mut g = FactorGraph::new(2);
    g.set_unary(0, 2.0); // var 0 biased up, var 1 free
    client
        .create_tenant(7, g.clone(), tenant_config(0x5C1))
        .unwrap();
    client.sweep(7, 300).unwrap();
    client.reset_stats(7).unwrap();
    client.sweep(7, 4000).unwrap();
    let parked = client.marginals(7).unwrap();
    assert!(
        (parked[1] - 0.5).abs() < 0.05,
        "uncoupled var sits near 1/2: {}",
        parked[1]
    );
    client.suspend(7).unwrap();
    assert!(client.stats(7).unwrap().suspended);
    // while parked: couple var 1 strongly to the biased var 0
    let op = ChurnOp::Add { v1: 0, v2: 1, beta: 1.5 };
    client.apply(7, vec![op.clone()]).unwrap();
    client.resume(7).unwrap();
    client.sweep(7, 300).unwrap();
    client.reset_stats(7).unwrap();
    client.sweep(7, 6000).unwrap();
    let fresh = client.marginals(7).unwrap();
    // the mirror of the tenant's post-churn graph is the ground truth
    let mut live = g.factors().map(|(id, _)| id).collect();
    ChurnTrace::apply(&mut g, &mut live, &op);
    let want = exact::enumerate(&g).marginals;
    for v in 0..2 {
        assert!(
            (fresh[v] - want[v]).abs() < 0.03,
            "v={v}: {} vs exact {}",
            fresh[v],
            want[v]
        );
    }
    assert!(
        (fresh[1] - parked[1]).abs() > 0.1,
        "marginals must reflect the churn, not the parked snapshot: \
         parked {} vs fresh {} (exact {})",
        parked[1],
        fresh[1],
        want[1]
    );
    coord.shutdown();
}

#[test]
fn prop_clamped_sites_never_flip_under_churn_and_clamp_interleavings() {
    // evidence is inviolable: whatever interleaving of clamp, unclamp,
    // churn, and sweeps a tenant's lifetime throws at the engine — on any
    // kernel, with or without a pool — a clamped site holds its evidence
    // state in every lane until the moment it is unclamped
    #[derive(Clone)]
    enum Op {
        Clamp(usize, u8),
        Unclamp(usize),
        Churn(usize, usize, f64),
        Sweep,
    }
    check("evidence is inviolable", 8, |gn: &mut Gen| {
        let k = gn.usize_in(2..=5);
        let n = gn.usize_in(4..=8);
        let mut base = FactorGraph::new_k(n, k);
        for _ in 0..gn.usize_in(2..=8) {
            let v1 = gn.usize_in(0..=n - 1);
            let mut v2 = gn.usize_in(0..=n - 1);
            if v1 == v2 {
                v2 = (v2 + 1) % n;
            }
            base.add_factor(PairFactor::potts(v1, v2, gn.f64_in(-0.6, 0.9)));
        }
        let lanes = gn.usize_in(1..=96);
        let seed = gn.u64();
        // script the interleaving once, then replay it on every
        // kernel × pool combination so all runs see the same lifetime
        let mut script = Vec::new();
        for _ in 0..14 {
            script.push(match gn.usize_in(0..=4) {
                0 => Op::Clamp(gn.usize_in(0..=n - 1), gn.usize_in(0..=k - 1) as u8),
                1 => Op::Unclamp(gn.usize_in(0..=n - 1)),
                2 => {
                    let v1 = gn.usize_in(0..=n - 1);
                    let v2 = (v1 + 1 + gn.usize_in(0..=n - 2)) % n;
                    Op::Churn(v1, v2, gn.f64_in(-0.5, 0.8))
                }
                _ => Op::Sweep,
            });
        }
        for &kernel in KernelKind::all() {
            for &pool in &[0usize, 3] {
                let mut g = base.clone();
                let mut eng = LanePdSampler::new(&g, lanes, seed).with_kernel(kernel);
                if pool > 0 {
                    eng = eng.with_pool(Arc::new(ThreadPool::new(pool)));
                }
                let mut evidence = std::collections::HashMap::new();
                for op in &script {
                    match op {
                        Op::Clamp(v, s) => {
                            eng.clamp(*v, *s).unwrap();
                            evidence.insert(*v, *s);
                        }
                        Op::Unclamp(v) => {
                            eng.unclamp(*v).unwrap();
                            evidence.remove(v);
                        }
                        Op::Churn(v1, v2, beta) => {
                            let id = g.add_factor(PairFactor::potts(*v1, *v2, *beta));
                            eng.add_factor(id, g.factor(id).unwrap());
                        }
                        Op::Sweep => eng.sweep(),
                    }
                    for (&v, &s) in &evidence {
                        for lane in [0, lanes / 2, lanes - 1] {
                            if eng.lane_value(v, lane) != s {
                                return Err(format!(
                                    "site {v} flipped off evidence {s} \
                                     ({} pool {pool}, lanes {lanes}, k {k})",
                                    kernel.name()
                                ));
                            }
                        }
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn clamping_commutes_with_suspend_resume() {
    // same evidence, three orderings — clamp→suspend→resume, clamp while
    // parked, and clamp after the park/unpark cycle — must leave three
    // same-seeded tenants in identical states: suspend parks trace
    // buffers, never sampler state, so evidence survives it untouched
    let mut coord = Coordinator::spawn(CoordinatorConfig {
        shards: 2,
        quantum: 0, // request-driven: deterministic
        ..Default::default()
    });
    let client = coord.client();
    let g = pdgibbs::workloads::potts_grid(2, 2, 3, 0.4);
    for t in [1u64, 2, 3] {
        client.create_tenant(t, g.clone(), tenant_config(0xC0FFEE)).unwrap();
    }
    client.clamp(1, 0, 2).unwrap();
    client.suspend(1).unwrap();
    client.resume(1).unwrap();
    client.suspend(2).unwrap();
    client.clamp(2, 0, 2).unwrap(); // lands while parked
    client.resume(2).unwrap();
    client.suspend(3).unwrap();
    client.resume(3).unwrap();
    client.clamp(3, 0, 2).unwrap();
    let mut answers = Vec::new();
    for t in [1u64, 2, 3] {
        let s = client.stats(t).unwrap();
        assert_eq!((s.clamped, s.k), (1, 3), "tenant {t}");
        client.sweep(t, 200).unwrap();
        client.reset_stats(t).unwrap();
        client.sweep(t, 4000).unwrap();
        answers.push(client.marginals(t).unwrap());
    }
    for m in &answers {
        // evidence entries of site 0 in the flattened n·(k−1) layout:
        // P(x₀=1) = 0 and P(x₀=2) = 1 exactly, every sweep, every chain
        assert_eq!(m[0], 0.0);
        assert_eq!(m[1], 1.0);
    }
    assert_eq!(answers[0], answers[1], "clamp-then-park diverged from clamp-while-parked");
    assert_eq!(answers[1], answers[2], "clamp-while-parked diverged from clamp-after-resume");
    coord.shutdown();
}

#[test]
fn dropping_a_tenant_with_queued_work_neither_panics_the_shard_nor_leaks_metrics() {
    // lifecycle edge case: drop a tenant while (a) the DRR scheduler has
    // it enrolled and hot, and (b) more foreground work for it is already
    // queued behind the drop. The shared shard thread must survive, the
    // queued requests must degrade into unknown-tenant counts, the
    // tenant's scoped metrics keys must be reclaimed, and the surviving
    // neighbor must keep receiving background grants.
    let mut coord = Coordinator::spawn(CoordinatorConfig {
        shards: 1, // both tenants share one shard thread
        quantum: 2048,
        ..Default::default()
    });
    let client = coord.client();
    client
        .create_tenant(1, pdgibbs::workloads::ising_grid(3, 3, 0.25, 0.0), tenant_config(0xD1))
        .unwrap();
    client
        .create_tenant(2, pdgibbs::workloads::ising_grid(3, 3, 0.25, 0.0), tenant_config(0xD2))
        .unwrap();
    // let background sweeping get hot on both tenants
    std::thread::sleep(std::time::Duration::from_millis(100));
    assert!(client.stats(1).unwrap().background_sweeps > 0);
    // queue a burst for tenant 1, then drop it, then queue MORE work for
    // the now-dead id — all in one FIFO stream
    client
        .apply(1, vec![ChurnOp::Add { v1: 0, v2: 4, beta: 0.3 }])
        .unwrap();
    client.sweep(1, 500).unwrap();
    assert!(client.drop_tenant(1).unwrap(), "tenant was hosted");
    client.sweep(1, 100).unwrap(); // queued after the drop: must degrade
    client
        .apply(1, vec![ChurnOp::Add { v1: 1, v2: 5, beta: 0.2 }])
        .unwrap();
    assert!(client.stats(1).is_err(), "dropped tenant is gone");
    // the shard thread survived: the neighbor still answers...
    let s2 = client.stats(2).unwrap();
    assert_eq!(s2.num_vars, 9);
    assert_eq!(client.marginals(2).unwrap().len(), 9);
    // ...and keeps receiving background grants after the ring shrank
    let before = client.stats(2).unwrap().background_sweeps;
    std::thread::sleep(std::time::Duration::from_millis(100));
    assert!(
        client.stats(2).unwrap().background_sweeps > before,
        "survivor starved after mid-hot drop"
    );
    // no leaked scope: tenant1.* keys reclaimed, tenant2.* still present
    let snap = coord.metrics().snapshot().dump();
    assert!(!snap.contains("tenant1."), "scope leaked: {snap}");
    assert!(snap.contains("tenant2."), "survivor scope missing");
    // post-drop requests were counted as unknown-tenant, not crashes
    assert!(coord.metrics().counter("shard0.unknown_tenant") >= 2);
    coord.shutdown();
}
