//! Cross-sampler integration: every sampler targets the same distribution.
//!
//! On models with exact oracles, all five samplers (sequential, chromatic,
//! primal–dual, blocked-PD, and — where applicable — Swendsen–Wang) must
//! produce marginals that agree with enumeration AND with each other.
//! This is the strongest whole-crate invariant: it couples graph,
//! dualization, blocking, BP, coloring and the samplers in one assertion.

use pdgibbs::graph::{FactorGraph, PairFactor};
use pdgibbs::inference::exact;
use pdgibbs::rng::Pcg64;
use pdgibbs::samplers::{
    empirical_marginals, BlockedPd, ChromaticGibbs, PdSampler, Sampler, SequentialGibbs,
    SwendsenWang,
};
use pdgibbs::util::proptest::{check, Gen};
use pdgibbs::workloads;

fn marginals_of(sampler: &mut dyn Sampler, seed: u64, burn: usize, keep: usize) -> Vec<f64> {
    let mut rng = Pcg64::seed(seed);
    empirical_marginals(sampler, &mut rng, burn, keep)
}

#[test]
fn all_samplers_agree_on_ferromagnetic_grid() {
    let g = workloads::ising_grid(3, 3, 0.45, 0.2);
    let want = exact::enumerate(&g).marginals;
    let tol = 0.015;
    let runs: Vec<(&str, Vec<f64>)> = vec![
        ("sequential", marginals_of(&mut SequentialGibbs::new(&g), 1, 500, 60_000)),
        ("chromatic", marginals_of(&mut ChromaticGibbs::new(&g), 2, 500, 60_000)),
        ("pd", marginals_of(&mut PdSampler::new(&g), 3, 1000, 90_000)),
        ("blocked", marginals_of(&mut BlockedPd::new(&g), 4, 300, 50_000)),
        ("sw", marginals_of(&mut SwendsenWang::new(&g), 5, 300, 50_000)),
    ];
    for (name, marg) in &runs {
        for v in 0..9 {
            assert!(
                (marg[v] - want[v]).abs() < tol,
                "{name} v={v}: {} vs exact {}",
                marg[v],
                want[v]
            );
        }
    }
}

#[test]
fn non_sw_samplers_agree_on_frustrated_model() {
    // mixed-sign couplings + fields: SW does not apply, others must agree
    let mut g = FactorGraph::new(8);
    for v in 0..8 {
        g.set_unary(v, 0.3 * ((v % 3) as f64 - 1.0));
    }
    for (i, &(a, b, beta)) in [
        (0usize, 1usize, 0.5f64),
        (1, 2, -0.4),
        (2, 3, 0.6),
        (3, 0, -0.5),
        (4, 5, 0.3),
        (5, 6, -0.6),
        (6, 7, 0.4),
        (7, 4, 0.2),
        (0, 4, -0.3),
        (2, 6, 0.35),
    ]
    .iter()
    .enumerate()
    {
        g.add_factor(PairFactor::ising(a, b, beta));
        let _ = i;
    }
    let want = exact::enumerate(&g).marginals;
    let tol = 0.015;
    let runs: Vec<(&str, Vec<f64>)> = vec![
        ("sequential", marginals_of(&mut SequentialGibbs::new(&g), 6, 500, 80_000)),
        ("chromatic", marginals_of(&mut ChromaticGibbs::new(&g), 7, 500, 80_000)),
        ("pd", marginals_of(&mut PdSampler::new(&g), 8, 1000, 120_000)),
        ("blocked", marginals_of(&mut BlockedPd::new(&g), 9, 300, 60_000)),
    ];
    for (name, marg) in &runs {
        for v in 0..8 {
            assert!(
                (marg[v] - want[v]).abs() < tol,
                "{name} v={v}: {} vs {}",
                marg[v],
                want[v]
            );
        }
    }
}

#[test]
fn prop_pd_matches_sequential_on_random_models() {
    // randomized cross-check without enumeration: PD and sequential land
    // on the same marginals (they target the same p(x))
    check("pd == sequential marginals", 6, |gn: &mut Gen| {
        let n = gn.usize_in(4..=8);
        let mut g = FactorGraph::new(n);
        for v in 0..n {
            g.set_unary(v, gn.f64_in(-0.8, 0.8));
        }
        for _ in 0..gn.usize_in(n..=2 * n) {
            let v1 = gn.usize_in(0..=n - 1);
            let mut v2 = gn.usize_in(0..=n - 1);
            if v1 == v2 {
                v2 = (v2 + 1) % n;
            }
            g.add_factor(PairFactor::new(v1, v2, gn.positive_table(1.2)));
        }
        let seq = marginals_of(&mut SequentialGibbs::new(&g), gn.u64(), 500, 60_000);
        let pd = marginals_of(&mut PdSampler::new(&g), gn.u64(), 1000, 90_000);
        for v in 0..n {
            if (seq[v] - pd[v]).abs() > 0.025 {
                return Err(format!(
                    "v={v}: sequential {} vs pd {}",
                    seq[v], pd[v]
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn updates_per_sweep_normalization() {
    // fig2b normalization contract: sequential counts n site updates per
    // sweep; PD counts n parallel updates (1 parallel step)
    let g = workloads::fully_connected_ising(10, |_, _| 0.01);
    assert_eq!(SequentialGibbs::new(&g).updates_per_sweep(), 10);
    assert_eq!(PdSampler::new(&g).updates_per_sweep(), 10);
}
