//! Properties of the dual model under slot churn:
//!
//! * cycling `remove_factor` → `add_factor` through the free slots
//!   mid-run restores the incidence lists and `base_field` to their
//!   pre-churn values — the invariant the coordinator relies on when a
//!   churn trace adds back a factor it previously dropped;
//! * the flat CSR-overlay incidence arena stays equal (as a multiset) to
//!   the nested reference incidence across arbitrary add/remove
//!   sequences, including across compaction boundaries.

use pdgibbs::duality::DualModel;
use pdgibbs::graph::{FactorGraph, PairFactor};
use pdgibbs::rng::Pcg64;
use pdgibbs::samplers::{PdSampler, Sampler};
use pdgibbs::util::proptest::{check, Gen};

/// (base_field, sorted incidence lists, live factor count).
fn snapshot(m: &DualModel) -> (Vec<f64>, Vec<Vec<(u32, f64)>>, usize) {
    let n = m.num_vars();
    let fields: Vec<f64> = (0..n).map(|v| m.base_field(v)).collect();
    let mut incs: Vec<Vec<(u32, f64)>> = (0..n).map(|v| m.incidence(v).to_vec()).collect();
    for inc in &mut incs {
        inc.sort_by_key(|e| e.0);
    }
    (fields, incs, m.num_factors())
}

#[test]
fn prop_churn_slot_reuse_restores_model() {
    check("churn slot reuse restores the dual model", 25, |gn: &mut Gen| {
        // random graph
        let n = gn.usize_in(3..=7);
        let mut g = FactorGraph::new(n);
        for v in 0..n {
            g.set_unary(v, gn.f64_in(-1.0, 1.0));
        }
        let mut ids = Vec::new();
        for _ in 0..gn.usize_in(n..=2 * n) {
            let v1 = gn.usize_in(0..=n - 1);
            let mut v2 = gn.usize_in(0..=n - 1);
            if v1 == v2 {
                v2 = (v2 + 1) % n;
            }
            ids.push(g.add_factor(PairFactor::new(v1, v2, gn.positive_table(1.5))));
        }

        // run a sampler mid-churn so the θ-reset path is exercised too
        let mut s = PdSampler::new(&g);
        let mut rng = Pcg64::seed(gn.u64());
        for _ in 0..20 {
            s.sweep(&mut rng);
        }
        let (fields0, incs0, live0) = snapshot(s.model());

        // remove a random subset of factors...
        let mut removed: Vec<usize> = Vec::new();
        for _ in 0..gn.usize_in(1..=ids.len()) {
            let pick = *gn.choose(&ids);
            if !removed.contains(&pick) {
                removed.push(pick);
                s.remove_factor(pick);
            }
        }
        for &id in &removed {
            if !s.model().free_slots().contains(&id) {
                return Err(format!("slot {id} missing from the free list"));
            }
        }
        for _ in 0..20 {
            s.sweep(&mut rng);
        }

        // ...then add the same factors back into the same (free) slots
        for &id in &removed {
            let f = g.factor(id).unwrap().clone();
            s.add_factor(id, &f);
        }
        if !s.model().free_slots().is_empty() {
            return Err(format!(
                "free list not drained by reuse: {:?}",
                s.model().free_slots()
            ));
        }
        for _ in 0..20 {
            s.sweep(&mut rng);
        }

        // the model must be exactly back to its pre-churn shape
        let (fields1, incs1, live1) = snapshot(s.model());
        if live1 != live0 {
            return Err(format!("live count {live1} != {live0}"));
        }
        // β entries are recomputed by the same deterministic factorization
        // from the same tables, so incidence must match bitwise
        if incs1 != incs0 {
            return Err(format!("incidence drift:\n{incs0:?}\nvs\n{incs1:?}"));
        }
        // base_field goes through -=α/+=α; allow f64 round-off only
        for v in 0..n {
            let (a, b) = (fields0[v], fields1[v]);
            if (a - b).abs() > 1e-12 * (1.0 + a.abs()) {
                return Err(format!("base_field drift at {v}: {a} vs {b}"));
            }
        }
        Ok(())
    });
}

/// Order-insensitive equality of the CSR-overlay view and the nested
/// reference incidence, over every variable.
fn assert_csr_matches_reference(m: &DualModel, ctx: &str) -> Result<(), String> {
    for v in 0..m.num_vars() {
        let mut csr = m.incidence_csr_logical(v);
        let mut nested = m.incidence(v).to_vec();
        csr.sort_by(|a, b| (a.0, a.1.to_bits()).cmp(&(b.0, b.1.to_bits())));
        nested.sort_by(|a, b| (a.0, a.1.to_bits()).cmp(&(b.0, b.1.to_bits())));
        if csr != nested {
            return Err(format!(
                "{ctx}: CSR/nested incidence mismatch at var {v}:\n{csr:?}\nvs\n{nested:?}"
            ));
        }
    }
    Ok(())
}

#[test]
fn prop_csr_overlay_matches_nested_reference_under_churn() {
    check("CSR overlay equals nested incidence under churn", 15, |gn: &mut Gen| {
        let n = gn.usize_in(3..=7);
        let mut g = FactorGraph::new(n);
        for v in 0..n {
            g.set_unary(v, gn.f64_in(-1.0, 1.0));
        }
        let mut live: Vec<usize> = Vec::new();
        for _ in 0..gn.usize_in(n..=2 * n) {
            let v1 = gn.usize_in(0..=n - 1);
            let mut v2 = gn.usize_in(0..=n - 1);
            if v1 == v2 {
                v2 = (v2 + 1) % n;
            }
            live.push(g.add_factor(PairFactor::new(v1, v2, gn.positive_table(1.5))));
        }
        let mut m = DualModel::from_graph(&g);
        let epoch0 = m.csr_epoch();
        assert_csr_matches_reference(&m, "after build")?;

        for step in 0..60 {
            let do_remove = !live.is_empty() && gn.u64() & 1 == 0;
            if do_remove {
                let id = live.swap_remove(gn.usize_in(0..=live.len() - 1));
                g.remove_factor(id);
                m.remove(id);
            } else {
                let v1 = gn.usize_in(0..=n - 1);
                let mut v2 = gn.usize_in(0..=n - 1);
                if v1 == v2 {
                    v2 = (v2 + 1) % n;
                }
                // the graph allocates the slot (reusing its free list);
                // the model mirrors it — the coordinator's exact flow
                let id = g.add_factor(PairFactor::new(v1, v2, gn.positive_table(1.5)));
                m.insert_at(id, g.factor(id).unwrap());
                live.push(id);
            }
            assert_csr_matches_reference(&m, &format!("after step {step}"))?;
            // hit a compaction boundary deterministically mid-churn
            // (on top of any automatic threshold-triggered rebuilds)
            if step == 20 || step == 40 {
                m.compact_incidence();
                assert_csr_matches_reference(&m, &format!("after compaction at {step}"))?;
            }
        }
        if m.csr_epoch() < epoch0 + 2 {
            return Err(format!(
                "compaction boundaries not exercised: epoch {} -> {}",
                epoch0,
                m.csr_epoch()
            ));
        }
        Ok(())
    });
}

#[test]
fn repeated_cycling_through_one_slot_is_stable() {
    // hammer a single slot: remove/re-add the same factor many times
    let mut g = FactorGraph::new(3);
    g.set_unary(0, 0.4);
    let keep = g.add_factor(PairFactor::ising(0, 1, 0.3));
    let cycled = g.add_factor(PairFactor::ising(1, 2, -0.6));
    let mut s = PdSampler::new(&g);
    let mut rng = Pcg64::seed(77);
    let (fields0, incs0, live0) = {
        let m = s.model();
        (
            vec![m.base_field(0), m.base_field(1), m.base_field(2)],
            (0..3).map(|v| m.incidence(v).to_vec()).collect::<Vec<_>>(),
            m.num_factors(),
        )
    };
    let f = g.factor(cycled).unwrap().clone();
    for _ in 0..50 {
        s.sweep(&mut rng);
        s.remove_factor(cycled);
        assert_eq!(s.model().free_slots(), &[cycled]);
        s.sweep(&mut rng);
        s.add_factor(cycled, &f);
        assert!(s.model().free_slots().is_empty());
    }
    let m = s.model();
    assert_eq!(m.num_factors(), live0);
    for v in 0..3 {
        assert!(
            (m.base_field(v) - fields0[v]).abs() < 1e-12,
            "field drift at {v}"
        );
        let mut got = m.incidence(v).to_vec();
        let mut want = incs0[v].clone();
        got.sort_by_key(|e| e.0);
        want.sort_by_key(|e| e.0);
        assert_eq!(got, want, "incidence drift at {v}");
    }
    let _ = keep;
}
