//! Malformed-input integration tests for the network serving edge, over
//! real TCP sockets: every hostile frame (bad syntax, truncated frames,
//! oversized payloads, unknown verbs, bad tenant ids) must come back as
//! a spanned, labeled `err parse …` / `err exec …` reply — and neither
//! the connection handler nor the shard threads may die. Backpressure
//! must surface as explicit `err overloaded …` rejections, never as
//! unbounded queueing or dropped connections.

use std::io::{BufRead, BufReader, Write};
use std::net::{Shutdown, TcpStream};
use std::time::{Duration, Instant};

use pdgibbs::coordinator::{Coordinator, CoordinatorConfig, NetConfig, NetServer};

fn spawn_edge(net: NetConfig, shards: usize, quantum: u64) -> (Coordinator, NetServer) {
    let coord = Coordinator::spawn(CoordinatorConfig {
        shards,
        quantum,
        ..Default::default()
    });
    let server = NetServer::spawn(coord.client(), coord.metrics().clone(), net, "127.0.0.1:0")
        .expect("bind test server on an ephemeral port");
    (coord, server)
}

/// A line-oriented wire client for the tests.
struct Wire {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Wire {
    fn connect(server: &NetServer) -> Wire {
        let stream = TcpStream::connect(server.addr()).expect("connect to test server");
        let reader = BufReader::new(stream.try_clone().expect("clone socket"));
        Wire { stream, reader }
    }

    fn send(&mut self, line: &str) {
        self.stream.write_all(line.as_bytes()).expect("send");
        self.stream.write_all(b"\n").expect("send newline");
    }

    fn recv(&mut self) -> String {
        let mut line = String::new();
        let n = self.reader.read_line(&mut line).expect("read reply");
        assert!(n > 0, "server closed the connection unexpectedly");
        line.trim_end().to_string()
    }

    fn roundtrip(&mut self, line: &str) -> String {
        self.send(line);
        self.recv()
    }
}

#[test]
fn every_malformed_request_is_a_spanned_labeled_diagnostic() {
    let (mut coord, mut server) = spawn_edge(NetConfig::default(), 1, 0);
    let mut wire = Wire::connect(&server);
    // (hostile line, span fragment, expected-token label fragment)
    let cases: &[(&str, &str, &str)] = &[
        ("zap 1 2", "span=0:3", "create|apply|sweep"),
        ("sweep nine 10", "span=6:10", "tenant id"),
        ("sweep 99999999999999999999 1", "span=6:26", "tenant id"),
        ("sweep 3", "span=7:7", "sweep count"),
        ("sweep 3 0", "span=8:9", "1..=1000000"),
        ("marginals 3 please", "span=12:18", "end of line"),
        ("apply 3 mul 0 1 0.5", "span=8:11", "add|del"),
        ("apply 3 add 0 1 inf", "span=16:19", "finite"),
        ("create 1 4 0", "span=11:12", "chain count"),
    ];
    for &(line, span, label) in cases {
        let reply = wire.roundtrip(line);
        assert!(
            reply.starts_with("err parse span="),
            "{line:?}: not a spanned diagnostic: {reply}"
        );
        assert!(reply.contains(span), "{line:?}: wrong span in {reply}");
        assert!(reply.contains("expected="), "{line:?}: no label in {reply}");
        assert!(reply.contains(label), "{line:?}: wrong label in {reply}");
        assert!(reply.contains("found="), "{line:?}: no found token in {reply}");
    }
    // the connection survived all of it, and so did the shard thread
    assert_eq!(wire.roundtrip("create 1 8"), "ok");
    assert!(wire.roundtrip("stats 1").starts_with("ok stats "));
    // a blank line is a keepalive: no reply, next request answers first
    wire.send("");
    assert_eq!(wire.roundtrip("drop 42"), "ok dropped=false");
    assert_eq!(
        coord.metrics().counter("net.parse_errors"),
        cases.len() as u64
    );
    server.shutdown();
    coord.shutdown();
}

#[test]
fn oversized_frames_resync_and_the_connection_survives() {
    let (mut coord, mut server) = spawn_edge(
        NetConfig {
            max_frame: 64,
            ..Default::default()
        },
        1,
        0,
    );
    let mut wire = Wire::connect(&server);
    // 200 bytes with no newline: over budget, rejected mid-frame
    wire.stream.write_all(&[b'x'; 200]).expect("send runaway frame");
    let reply = wire.recv();
    assert!(reply.starts_with("err parse span=0:"), "{reply}");
    assert!(reply.contains("frame of at most 64 bytes"), "{reply}");
    assert!(reply.contains("bytes without a newline"), "{reply}");
    // everything up to the runaway frame's eventual newline is discarded
    // without further replies; the stream then resyncs and the next
    // request is served normally
    wire.send("");
    assert_eq!(wire.roundtrip("drop 7"), "ok dropped=false");
    server.shutdown();
    coord.shutdown();
}

#[test]
fn truncated_frames_report_eof_before_the_connection_closes() {
    let (mut coord, mut server) = spawn_edge(NetConfig::default(), 1, 0);
    let mut wire = Wire::connect(&server);
    // bytes arrive, the newline never does: half-close the write side
    wire.stream.write_all(b"sweep 1").expect("send partial frame");
    wire.stream.shutdown(Shutdown::Write).expect("half-close");
    let reply = wire.recv();
    assert_eq!(
        reply,
        "err parse span=0:7 expected=newline-terminated frame; \
         found=end of stream after 7 bytes"
    );
    // after the diagnostic the server closes the connection cleanly
    let mut rest = String::new();
    assert_eq!(wire.reader.read_line(&mut rest).expect("read EOF"), 0);
    server.shutdown();
    coord.shutdown();
}

#[test]
fn bad_tenant_ids_degrade_to_exec_errors_and_the_shard_survives() {
    let (mut coord, mut server) = spawn_edge(NetConfig::default(), 2, 0);
    let mut wire = Wire::connect(&server);
    // queries on a tenant nobody created: execution errors, not crashes
    assert!(wire.roundtrip("marginals 404").starts_with("err exec "));
    assert!(wire.roundtrip("stats 404").starts_with("err exec "));
    assert_eq!(wire.roundtrip("drop 404"), "ok dropped=false");
    // fire-and-forget verbs are acked at admission; the shard absorbs
    // the unknown-tenant request without dying
    assert_eq!(wire.roundtrip("sweep 404 5"), "ok");
    assert_eq!(wire.roundtrip("apply 404 add 0 1 0.5"), "ok");
    // both shards still serve real traffic afterwards
    assert_eq!(wire.roundtrip("create 404 6 4 9"), "ok");
    assert!(wire.roundtrip("stats 404").starts_with("ok stats vars=6 "));
    assert!(wire.roundtrip("marginals 404").starts_with("ok marginals n=6 "));
    for shard in 0..2 {
        assert_eq!(
            coord.metrics().counter(&format!("shard{shard}.sched_desync")),
            0,
            "shard {shard} desynced"
        );
    }
    server.shutdown();
    coord.shutdown();
}

#[test]
fn backpressure_rejects_with_explicit_overloaded_replies() {
    // tiny admission bound, batching off so every sweep is its own
    // shard message, background sweeping off for determinism
    let (mut coord, mut server) = spawn_edge(
        NetConfig {
            max_tenant_depth: 1,
            batch: false,
            ..Default::default()
        },
        1,
        0,
    );
    let mut wire = Wire::connect(&server);
    assert_eq!(wire.roundtrip("create 9 32 8 7"), "ok");
    assert_eq!(wire.roundtrip("apply 9 add 0 1 0.3 add 1 2 0.3"), "ok");
    // each sweep request is acked at admission but takes the shard tens
    // of milliseconds to execute, so a fast closed loop outruns it and
    // piles depth onto the tenant queue
    let (mut ok, mut overloaded) = (0u64, 0u64);
    for _ in 0..64 {
        let reply = wire.roundtrip("sweep 9 20000");
        if reply == "ok" {
            ok += 1;
        } else {
            assert!(
                reply.starts_with("err overloaded tenant 9 depth="),
                "unexpected reply under load: {reply}"
            );
            assert!(reply.ends_with("limit=1"), "{reply}");
            overloaded += 1;
        }
    }
    assert!(ok >= 1, "no sweep was ever admitted");
    assert!(
        overloaded >= 1,
        "64 back-to-back sweeps never tripped the depth=1 bound"
    );
    assert!(coord.metrics().counter("net.overloaded") >= overloaded);
    // rejected clients retry and eventually get through once the shard
    // drains — overload is explicit and transient, not a dead connection
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let reply = wire.roundtrip("marginals 9");
        if reply.starts_with("ok marginals n=32 ") {
            break;
        }
        assert!(
            reply.starts_with("err overloaded "),
            "retry loop saw a non-overload failure: {reply}"
        );
        assert!(Instant::now() < deadline, "shard never drained its backlog");
        std::thread::sleep(Duration::from_millis(50));
    }
    server.shutdown();
    coord.shutdown();
}

#[test]
fn dropped_tenants_queued_work_does_not_poison_a_recreated_id() {
    // depth-ledger coverage: a tenant dropped while work is still queued
    // must have every queued entry repaid when the shard dequeues it, so
    // re-creating the same id cannot inherit phantom depth and be stuck
    // behind `err overloaded` forever
    let (mut coord, mut server) = spawn_edge(
        NetConfig {
            max_tenant_depth: 4,
            batch: false,
            ..Default::default()
        },
        1,
        0,
    );
    let mut wire = Wire::connect(&server);
    assert_eq!(wire.roundtrip("create 5 32 8 7"), "ok");
    // pile admitted-but-unprocessed sweeps onto the tenant queue
    let mut admitted = 0u64;
    for _ in 0..16 {
        if wire.roundtrip("sweep 5 20000") == "ok" {
            admitted += 1;
        }
    }
    assert!(admitted >= 1, "no sweep was ever admitted");
    let deadline = Instant::now() + Duration::from_secs(30);
    let mut retry = |wire: &mut Wire, req: &str, want_prefix: &str| loop {
        let reply = wire.roundtrip(req);
        if reply.starts_with(want_prefix) {
            return;
        }
        assert!(
            reply.starts_with("err overloaded "),
            "{req:?}: non-overload failure: {reply}"
        );
        assert!(Instant::now() < deadline, "{req:?} never got through");
        std::thread::sleep(Duration::from_millis(20));
    };
    // drop while the backlog is still draining, then re-create the same
    // id with a different shape
    retry(&mut wire, "drop 5", "ok dropped=true");
    retry(&mut wire, "create 5 6 4 9", "ok");
    // the recreated id must become servable — a leaked ledger entry from
    // the dropped incarnation would trip admission on every retry
    retry(&mut wire, "stats 5", "ok stats vars=6 ");
    // and once the queue drains, the ledger reads zero: fully repaid
    assert_eq!(coord.client().tenant_depth(5), 0, "depth ledger leaked");
    server.shutdown();
    coord.shutdown();
}

#[test]
fn create_with_minibatch_policy_surfaces_in_stats() {
    let (mut coord, mut server) = spawn_edge(NetConfig::default(), 1, 0);
    let mut wire = Wire::connect(&server);
    assert_eq!(wire.roundtrip("create 11 32 4 9 minibatch:16:4"), "ok");
    let stats = wire.roundtrip("stats 11");
    assert!(stats.contains(" policy=minibatch:16:4"), "{stats}");
    assert_eq!(wire.roundtrip("create 12 32 4 9"), "ok");
    let stats = wire.roundtrip("stats 12");
    assert!(stats.contains(" policy=exact"), "{stats}");
    // a malformed policy is a spanned parse error, not a dead connection
    let reply = wire.roundtrip("create 13 8 minibatch:zero");
    assert!(reply.starts_with("err parse "), "{reply}");
    assert!(reply.contains("sweep policy"), "{reply}");
    assert_eq!(wire.roundtrip("drop 11"), "ok dropped=true");
    server.shutdown();
    coord.shutdown();
}

#[test]
fn create_with_blocked_policy_surfaces_plan_in_stats() {
    let (mut coord, mut server) = spawn_edge(NetConfig::default(), 1, 0);
    let mut wire = Wire::connect(&server);
    assert_eq!(wire.roundtrip("create 21 4 64 9 blocked:4:8"), "ok");
    let stats = wire.roundtrip("stats 21");
    assert!(stats.contains(" policy=blocked:4:8"), "{stats}");
    assert!(stats.contains(" blocks=0 blocked_vars=0 tree_slots=0"), "{stats}");
    // strong couplings + sweeps: the agreement EWMAs must grow a plan,
    // and the plan summary must surface over the wire
    assert_eq!(
        wire.roundtrip("apply 21 add 0 1 0.9 add 1 2 0.9 add 2 3 0.9"),
        "ok"
    );
    assert_eq!(wire.roundtrip("sweep 21 64"), "ok");
    let field = |stats: &str, key: &str| -> usize {
        stats
            .split(&format!("{key}="))
            .nth(1)
            .and_then(|s| s.split_whitespace().next())
            .and_then(|s| s.parse().ok())
            .unwrap_or_else(|| panic!("missing {key} in {stats}"))
    };
    let deadline = Instant::now() + Duration::from_secs(30);
    let stats = loop {
        // sweeps are acknowledged at admission; poll until they landed
        let stats = wire.roundtrip("stats 21");
        if field(&stats, "sweeps") >= 64 {
            break stats;
        }
        assert!(Instant::now() < deadline, "sweeps never landed: {stats}");
        std::thread::sleep(Duration::from_millis(20));
    };
    assert!(field(&stats, "blocks") >= 1, "{stats}");
    assert!(field(&stats, "blocked_vars") >= 2, "{stats}");
    assert!(field(&stats, "tree_slots") >= 1, "{stats}");
    server.shutdown();
    coord.shutdown();
}

#[test]
fn kstate_create_clamp_and_unclamp_over_the_wire() {
    let (mut coord, mut server) = spawn_edge(NetConfig::default(), 2, 0);
    let mut wire = Wire::connect(&server);
    // k=3 tenant: stats advertises cardinality and live evidence count
    assert_eq!(wire.roundtrip("create 31 4 8 7 k=3"), "ok");
    let stats = wire.roundtrip("stats 31");
    assert!(stats.contains(" k=3"), "{stats}");
    assert!(stats.contains(" clamped=0"), "{stats}");
    // agreement couplings (`add` acts as a Potts bonus on K-state
    // tenants) + evidence, then serve conditional marginals: requests are
    // FIFO per shard, so the marginals read runs after the sweeps
    assert_eq!(
        wire.roundtrip("apply 31 add 0 1 0.4 add 1 2 0.4 add 2 3 0.4"),
        "ok"
    );
    assert_eq!(wire.roundtrip("clamp 31 0 2"), "ok");
    assert!(wire.roundtrip("stats 31").contains(" clamped=1"));
    assert_eq!(wire.roundtrip("sweep 31 50"), "ok");
    let m = wire.roundtrip("marginals 31");
    assert!(m.starts_with("ok marginals n=8 "), "{m}");
    let vals: Vec<f64> = m
        .split_whitespace()
        .skip(3)
        .map(|t| t.parse().expect("marginal value"))
        .collect();
    assert_eq!(vals.len(), 8, "4 vars × (k−1) states: {m}");
    // evidence is exact on the wire: P(x₀=1) = 0, P(x₀=2) = 1
    assert_eq!(vals[0], 0.0, "{m}");
    assert_eq!(vals[1], 1.0, "{m}");
    assert_eq!(wire.roundtrip("unclamp 31 0"), "ok");
    assert!(wire.roundtrip("stats 31").contains(" clamped=0"));
    // execution-time rejections: parse-legal states that exceed the
    // tenant's cardinality, out-of-graph sites, ghost tenants — all
    // `err exec`, never a dead connection
    assert!(
        wire.roundtrip("clamp 31 0 5").starts_with("err exec clamp rejected: "),
        "state 5 on a k=3 tenant must be an exec error"
    );
    assert!(
        wire.roundtrip("clamp 31 9 0").starts_with("err exec clamp rejected: "),
        "site 9 of a 4-var tenant must be an exec error"
    );
    assert!(wire.roundtrip("clamp 404 0 0").starts_with("err exec "));
    assert!(wire.roundtrip("unclamp 404 0").starts_with("err exec "));
    // formerly rejected: minibatched K-state tenants now host cleanly,
    // stats advertising both the policy and the cardinality
    assert_eq!(wire.roundtrip("create 32 8 4 7 k=4 minibatch:16:4"), "ok");
    let stats = wire.roundtrip("stats 32");
    assert!(stats.contains(" k=4"), "{stats}");
    assert!(stats.contains(" policy=minibatch:16:4"), "{stats}");
    server.shutdown();
    coord.shutdown();
}

#[test]
fn every_policy_cardinality_clamp_combo_hosts_on_a_reusable_id() {
    // regression for the create-reject-recreate lifecycle: one tenant id
    // cycles through every policy × k × clamp combination — each create
    // must succeed, serve evidence, and leave the id reusable after the
    // drop; a duplicate create and a degenerate policy both refuse the
    // id WITHOUT consuming it
    let (mut coord, mut server) = spawn_edge(NetConfig::default(), 2, 0);
    let mut wire = Wire::connect(&server);
    for policy in ["minibatch:2:2", "blocked:4:8"] {
        for k in [3usize, 5, 8] {
            let create = format!("create 77 6 8 7 k={k} {policy}");
            assert_eq!(wire.roundtrip(&create), "ok", "{create}");
            // duplicate id: refused, but the hosted tenant is untouched
            assert!(
                wire.roundtrip(&create).starts_with("err exec "),
                "duplicate create must be refused"
            );
            assert_eq!(
                wire.roundtrip("apply 77 add 0 1 0.4 add 1 2 0.4 add 2 3 -0.3"),
                "ok"
            );
            assert_eq!(wire.roundtrip(&format!("clamp 77 1 {}", k - 1)), "ok");
            assert_eq!(wire.roundtrip("sweep 77 8"), "ok");
            let stats = wire.roundtrip("stats 77");
            assert!(stats.contains(&format!(" k={k}")), "{stats}");
            assert!(stats.contains(&format!(" policy={policy}")), "{stats}");
            assert!(stats.contains(" clamped=1"), "{stats}");
            assert_eq!(wire.roundtrip("drop 77"), "ok dropped=true");
        }
    }
    // after six host/drop cycles and six refused duplicates, the id is
    // still fully reusable — no rejection consumed it
    assert_eq!(wire.roundtrip("create 77 6 8 7 k=3 exact"), "ok");
    assert!(wire.roundtrip("stats 77").contains(" policy=exact"));
    server.shutdown();
    coord.shutdown();
}

#[test]
fn malformed_kstate_frames_are_spanned_over_the_wire() {
    let (mut coord, mut server) = spawn_edge(NetConfig::default(), 1, 0);
    let mut wire = Wire::connect(&server);
    // (hostile line, span fragment, expected-token label fragment)
    let cases: &[(&str, &str, &str)] = &[
        ("create 1 9 k=9", "span=11:14", "k=2..=8"),
        ("create 1 9 k=one", "span=11:16", "k=2..=8"),
        ("clamp 3 4", "span=9:9", "evidence state"),
        ("clamp 3 4 8", "span=10:11", "0..=7"),
        ("unclamp 3", "span=9:9", "variable index"),
        ("unclamp 3 4 5", "span=12:13", "end of line"),
    ];
    for &(line, span, label) in cases {
        let reply = wire.roundtrip(line);
        assert!(
            reply.starts_with("err parse span="),
            "{line:?}: not a spanned diagnostic: {reply}"
        );
        assert!(reply.contains(span), "{line:?}: wrong span in {reply}");
        assert!(reply.contains(label), "{line:?}: wrong label in {reply}");
        assert!(reply.contains("found="), "{line:?}: no found token in {reply}");
    }
    // the connection and the shard both survived the abuse
    assert_eq!(wire.roundtrip("create 1 4 k=3"), "ok");
    assert!(wire.roundtrip("stats 1").contains(" k=3"));
    assert_eq!(
        coord.metrics().counter("net.parse_errors"),
        cases.len() as u64
    );
    server.shutdown();
    coord.shutdown();
}

#[test]
fn subscribe_streams_events_then_ok() {
    let (mut coord, mut server) = spawn_edge(NetConfig::default(), 1, 0);
    let mut wire = Wire::connect(&server);
    assert_eq!(wire.roundtrip("create 2 4 8 5"), "ok");
    wire.send("subscribe 2 3 10");
    let mut last_sweeps = 0usize;
    for index in 0..3 {
        let event = wire.recv();
        assert!(
            event.starts_with(&format!("event index={index} sweeps=")),
            "event {index}: {event}"
        );
        let sweeps: usize = event
            .split("sweeps=")
            .nth(1)
            .and_then(|s| s.split_whitespace().next())
            .and_then(|s| s.parse().ok())
            .expect("sweeps field");
        assert!(
            sweeps >= last_sweeps + 10,
            "event {index} reflects too few sweeps: {event}"
        );
        last_sweeps = sweeps;
        assert!(event.contains("mean="), "{event}");
    }
    assert_eq!(wire.recv(), "ok");
    // a subscription to a ghost tenant degrades into one exec error
    assert!(wire.roundtrip("subscribe 404 2 5").starts_with("err exec "));
    server.shutdown();
    coord.shutdown();
}
