//! Tier 3 — the statistical exactness suite: every sampling path the
//! crate ships is gated against exact inference on the scenario zoo.
//!
//! PRs 2–4's bit-identity tests prove every kernel/pool/shard samples the
//! *same* trajectory; this suite proves the trajectory targets the
//! *right* distribution (the paper's exactness claim). Coverage per the
//! ISSUE-5 acceptance criteria:
//!
//! * all 5 classical samplers (sequential, chromatic, scalar PD,
//!   blocked-PD, Swendsen–Wang),
//! * the lane engine under scalar + tiled kernels × pool sizes {0, 4},
//! * `PdEnsemble` and the live coordinator tenant path,
//! * dense `K_n` scenarios with no small coloring,
//! * churn sequences crossing the degree-6 x-table-cache cap both ways,
//! * minibatched and adaptively-blocked sweep policies (different
//!   trajectories, same stationary law) per kernel × pool,
//! * K-state Potts scenarios below and above the critical coupling, and
//!   evidence scenarios gated against the exact *conditional* law, on
//!   the classical, lane (kernel × pool), ensemble, and coordinator
//!   paths.
//!
//! Everything is seed-fixed and thresholded by precomputed statistics
//! (see `rust/src/validation/harness.rs` and `docs/TESTING.md`) —
//! deterministic, CI-safe, no flakes. The calibration/power tests at the
//! bottom keep the gates honest: ground-truth iid draws must pass, and
//! deliberately biased distributions must fail.

use std::sync::Arc;

use pdgibbs::duality::{BlockPolicy, MinibatchPolicy};
use pdgibbs::engine::{EngineConfig, KernelKind, SweepPolicy};
use pdgibbs::samplers::{
    BlockedPd, ChromaticGibbs, KStateGibbs, PdSampler, SequentialGibbs, SwendsenWang,
};
use pdgibbs::util::ThreadPool;
use pdgibbs::validation::{
    validate, validate_conditioned, ClassicalPath, CoordinatorPath, EnsemblePath, ExactForward,
    GateConfig, LanePath, SamplingPath, ValidationReport,
};
use pdgibbs::workloads::scenarios::{self, Scenario};

/// Gate a path on a static scenario; returns the report so callers can
/// additionally assert which gates ran.
fn check_static(path: &mut dyn SamplingPath, s: &Scenario, samples: usize) -> ValidationReport {
    assert!(s.churn.is_empty(), "{} is a churn scenario", s.name);
    let r = validate(path, &s.graph, s.name, &GateConfig::with_budget(samples, s.tau));
    println!("{}", r.summary());
    r.assert_passed();
    r
}

/// Warm a path up on the base model, apply the scenario's churn, and gate
/// against the materialized final graph.
fn check_churn(path: &mut dyn SamplingPath, s: &Scenario, samples: usize) {
    assert!(!s.churn.is_empty(), "{} is a static scenario", s.name);
    path.advance(200);
    assert!(path.apply_churn(&s.churn), "path must support churn");
    let r = validate(path, &s.final_graph(), s.name, &GateConfig::with_budget(samples, s.tau));
    println!("{}", r.summary());
    r.assert_passed();
}

// -- classical samplers -----------------------------------------------------

#[test]
fn sequential_gibbs_passes_gates() {
    for (name, samples) in [
        ("chain8-below", 5000),
        ("grid3x3-below", 4000),
        ("triangle-above", 2000),
    ] {
        let s = scenarios::by_name(name);
        let mut p = ClassicalPath::new(Box::new(SequentialGibbs::new(&s.graph)), 0x5E01);
        check_static(&mut p, &s, samples);
    }
}

#[test]
fn chromatic_gibbs_passes_gates_even_where_coloring_degenerates() {
    // kn10-dense needs 10 colors — zero within-sweep parallelism, but the
    // kernel must stay exact
    for (name, samples) in [("chain8-below", 5000), ("kn10-dense", 2500)] {
        let s = scenarios::by_name(name);
        let chrom = ChromaticGibbs::new(&s.graph);
        if name == "kn10-dense" {
            assert_eq!(chrom.num_colors(), 10, "K_10 admits no small coloring");
        }
        let mut p = ClassicalPath::new(Box::new(chrom), 0x5E02);
        check_static(&mut p, &s, samples);
    }
}

#[test]
fn scalar_pd_passes_gates_across_regimes() {
    for (name, samples) in [
        ("chain8-below", 5000),
        ("chain8-at", 3000),
        ("kn12-paper", 4000),
    ] {
        let s = scenarios::by_name(name);
        let mut p = ClassicalPath::new(Box::new(PdSampler::new(&s.graph)), 0x5E03);
        check_static(&mut p, &s, samples);
    }
}

#[test]
fn blocked_pd_passes_gates() {
    // on the chain the spanning tree covers every factor: blocked-PD
    // degenerates to exact joint draws — still must pass, even above the
    // coupling threshold
    for (name, samples) in [("grid3x3-below", 4000), ("chain8-above", 2000)] {
        let s = scenarios::by_name(name);
        let mut p = ClassicalPath::new(Box::new(BlockedPd::new(&s.graph)), 0x5E04);
        check_static(&mut p, &s, samples);
    }
}

#[test]
fn swendsen_wang_passes_gates() {
    for (name, samples) in [("grid3x3-below", 4000), ("chain8-above", 2000)] {
        let s = scenarios::by_name(name);
        assert!(s.is_ferromagnetic(), "SW applicability");
        let mut p = ClassicalPath::new(Box::new(SwendsenWang::new(&s.graph)), 0x5E05);
        check_static(&mut p, &s, samples);
    }
}

// -- lane engine: kernels × pools -------------------------------------------

#[test]
fn lane_engine_scalar_and_tiled_kernels_pass_gates_at_pool_0_and_4() {
    let s = scenarios::by_name("grid3x3-below");
    for kernel in [KernelKind::Scalar, KernelKind::Tiled] {
        for pool_threads in [0usize, 4] {
            let pool = (pool_threads > 0).then(|| Arc::new(ThreadPool::new(pool_threads)));
            let mut p = LanePath::new(
                s.graph.clone(),
                EngineConfig { lanes: 64, seed: 0xA5, kernel, ..EngineConfig::default() },
                pool,
            );
            check_static(&mut p, &s, 16_384);
        }
    }
}

#[test]
fn lane_engine_tiled_passes_gates_at_the_coupling_threshold() {
    // 64 chains make the high-tau "at threshold" scenarios affordable
    for (name, samples) in [("chain8-at", 16_384), ("grid3x3-at", 8192)] {
        let s = scenarios::by_name(name);
        let mut p = LanePath::with_lanes(s.graph.clone(), 64, 0xA6);
        check_static(&mut p, &s, samples);
    }
}

#[test]
fn lane_engine_passes_gates_on_dense_kn_without_coloring() {
    // the paper's motivation: K_n admits no small coloring, yet the lane
    // engine updates every site in parallel and must stay exact. Every
    // variable's degree exceeds the x-table cap, so this pins the
    // accumulate fallback path. Samples scale with the state space so
    // the joint chi-square gate stays testable (expected counts clear
    // the pooling floor) even on the 2^12-state model.
    for name in ["kn10-dense", "kn12-paper"] {
        let s = scenarios::by_name(name);
        let samples = (16usize << s.graph.num_vars()).max(16_384);
        for (kernel, pool_threads) in [(KernelKind::Tiled, 0usize), (KernelKind::Scalar, 4)] {
            let pool = (pool_threads > 0).then(|| Arc::new(ThreadPool::new(pool_threads)));
            let mut p = LanePath::new(
                s.graph.clone(),
                EngineConfig { lanes: 64, seed: 0xA7, kernel, ..EngineConfig::default() },
                pool,
            );
            assert!(
                p.engine().model().x_table(0).is_none(),
                "dense vars must use the accumulate fallback"
            );
            let r = check_static(&mut p, &s, samples);
            assert!(
                r.chi2.is_some(),
                "{name}: the joint chi-square gate must actually run"
            );
        }
    }
}

#[test]
fn lane_engine_stays_exact_through_churn_across_the_table_cache_cap() {
    for name in ["churn-cross-up", "churn-cross-down"] {
        let s = scenarios::by_name(name);
        for kernel in [KernelKind::Tiled, KernelKind::Scalar] {
            let mut p = LanePath::new(
                s.graph.clone(),
                EngineConfig { lanes: 64, seed: 0xA8, kernel, ..EngineConfig::default() },
                None,
            );
            assert!(
                p.engine().model().x_table(0).is_some(),
                "hub starts under the cache cap"
            );
            check_churn(&mut p, &s, 16_384);
            let expect_cached = name == "churn-cross-down";
            assert_eq!(
                p.engine().model().x_table(0).is_some(),
                expect_cached,
                "{name}: hub cache state after churn"
            );
        }
    }
}

// -- minibatched sweeps: MIN-Gibbs subsampling under the same gates ---------

/// An aggressive subsampling policy for the 12-var hub scenario: the λ
/// floor keeps the acceptance correction (not excess auxiliary slack)
/// carrying the exactness burden, and θ-stride 2 exercises the stale-θ
/// half of the minibatch trade.
fn hub_minibatch_policy() -> SweepPolicy {
    SweepPolicy::Minibatch(MinibatchPolicy {
        degree_threshold: 4,
        lambda_scale: 0.25,
        lambda_min: 1.0,
        theta_stride: 2,
    })
}

#[test]
fn minibatch_lane_paths_pass_gates_across_kernels_and_pools() {
    // the corrected subsampled chain must clear the same z/TV/chi-square
    // gates as every exact path — per kernel, at pool sizes {0, 4}
    let s = scenarios::by_name("hub12-minibatch");
    for &kernel in KernelKind::all() {
        for pool_threads in [0usize, 4] {
            let pool = (pool_threads > 0).then(|| Arc::new(ThreadPool::new(pool_threads)));
            let mut p = LanePath::new(
                s.graph.clone(),
                EngineConfig { lanes: 64, seed: 0xB1, kernel, sweep: hub_minibatch_policy() },
                pool,
            );
            let m = p.engine().model();
            assert!(m.mb_plan(0).is_some(), "the hub must sweep minibatched");
            assert!(m.mb_plan(1).is_none(), "low-degree leaves stay exact");
            let cfg = GateConfig::with_budget(16_384, s.tau);
            let name = format!("hub12-minibatch/{}-pool{pool_threads}", kernel.name());
            let r = validate(&mut p, &s.graph, &name, &cfg);
            println!("{}", r.summary());
            r.assert_passed();
        }
    }
}

#[test]
fn minibatch_lane_paths_stay_exact_through_hub_churn() {
    // churn removes a hub edge, re-adds it sign-flipped, and couples two
    // leaves: the alias plan must rebuild and the rebuilt chain must
    // still pass the gates against the final graph
    let s = scenarios::by_name("hub12-minibatch");
    for kernel in [KernelKind::Scalar, KernelKind::Tiled] {
        let mut p = LanePath::new(
            s.graph.clone(),
            EngineConfig { lanes: 64, seed: 0xB2, kernel, sweep: hub_minibatch_policy() },
            None,
        );
        check_churn(&mut p, &s, 16_384);
        assert!(
            p.engine().model().mb_plan(0).is_some(),
            "hub plan must survive churn (degree is unchanged)"
        );
    }
}

// -- blocked sweeps: adaptive tree-blocking under the same gates ------------

/// A small cap with a short epoch: plans re-form often enough that the
/// gates sample across several re-planning boundaries, not one frozen
/// plan.
fn blocked_policy() -> SweepPolicy {
    SweepPolicy::Blocked(BlockPolicy { cap: 4, epoch: 8 })
}

#[test]
fn blocked_lane_paths_pass_gates_across_kernels_and_pools() {
    // the jointly-drawn tree blocks change the trajectory, not the law:
    // the blocked chain must clear the same z/TV/chi-square gates as
    // every exact path, on the above-critical grid where blocking is
    // actually exercised — per kernel, at pool sizes {0, 4}
    let s = scenarios::by_name("grid3x3-above");
    for &kernel in KernelKind::all() {
        for pool_threads in [0usize, 4] {
            let pool = (pool_threads > 0).then(|| Arc::new(ThreadPool::new(pool_threads)));
            let mut p = LanePath::new(
                s.graph.clone(),
                EngineConfig { lanes: 64, seed: 0xD1, kernel, sweep: blocked_policy() },
                pool,
            );
            let cfg = GateConfig::with_budget(8192, s.tau);
            let name = format!("grid3x3-above/{}-pool{pool_threads}", kernel.name());
            let r = validate(&mut p, &s.graph, &name, &cfg);
            println!("{}", r.summary());
            r.assert_passed();
            assert!(
                p.engine().block_summary().0 >= 1,
                "{name}: the above-critical grid must actually grow blocks"
            );
        }
    }
}

#[test]
fn blocked_lane_paths_stay_exact_through_churn() {
    // churn removes a mid-chain factor and grows a hub across the table
    // cap: the plan is invalidated eagerly, recycled slots restart with
    // neutral stats, and the re-planned chain must still pass the gates
    // against the final graph
    let s = scenarios::by_name("churn-cross-up");
    for kernel in [KernelKind::Scalar, KernelKind::Tiled] {
        let mut p = LanePath::new(
            s.graph.clone(),
            EngineConfig { lanes: 64, seed: 0xD2, kernel, sweep: blocked_policy() },
            None,
        );
        check_churn(&mut p, &s, 16_384);
    }
}

// -- ensemble and coordinator serving paths ---------------------------------

#[test]
fn pd_ensemble_passes_gates_including_churn() {
    let s = scenarios::by_name("grid3x3-below");
    let mut p = EnsemblePath::new(s.graph.clone(), 16, 0xE1, None);
    check_static(&mut p, &s, 16_384);

    let s = scenarios::by_name("churn-cross-down");
    let mut p = EnsemblePath::new(s.graph.clone(), 16, 0xE2, None);
    check_churn(&mut p, &s, 16_384);
}

#[test]
fn coordinator_tenant_path_passes_marginal_gates() {
    // the serving path exposes pooled marginals only (visit_states is
    // unobservable), so the harness runs the tau-discounted marginal
    // z-gate; background sweeping is off for determinism
    let s = scenarios::by_name("grid3x3-below");
    let mut p = CoordinatorPath::new(s.graph.clone(), 2, 0, 8, 0xC1);
    check_static(&mut p, &s, 8192);
}

#[test]
fn coordinator_tenant_path_stays_exact_through_churn() {
    let s = scenarios::by_name("churn-cross-up");
    let mut p = CoordinatorPath::new(s.graph.clone(), 2, 0, 8, 0xC2);
    check_churn(&mut p, &s, 8192);
}

// -- K-state Potts and evidence: conditional exactness end to end -----------

/// Gate a path on a K-state and/or evidence scenario: push the
/// scenario's evidence through the path's own clamp API, then validate
/// against the exact *conditional* law. (For evidence-free Potts
/// scenarios this degenerates to the unconditional gates over base-k
/// joint codes.)
fn check_kstate(path: &mut dyn SamplingPath, s: &Scenario, samples: usize, name: &str) {
    assert!(s.churn.is_empty(), "{} is a churn scenario", s.name);
    assert_eq!(path.k(), s.k, "{name}: path cardinality");
    for &(v, st) in &s.evidence {
        assert!(path.clamp(v, st), "{name}: clamp ({v}, {st}) refused");
    }
    let cfg = GateConfig::with_budget(samples, s.tau);
    let r = validate_conditioned(path, &s.graph, &s.evidence, name, &cfg);
    println!("{}", r.summary());
    r.assert_passed();
}

/// The three cardinality/evidence scenarios with per-path sample
/// budgets: the above-critical Potts grid mixes slowly (tau 120), so it
/// leans on the tau-discounted thresholds rather than a bigger budget.
const KSTATE_SCENARIOS: [(&str, usize); 3] = [
    ("potts3-grid3x3-below", 8192),
    ("potts3-grid3x3-above", 8192),
    ("chain8-evidence", 5000),
];

#[test]
fn classical_kstate_gibbs_passes_gates_on_potts_and_evidence_scenarios() {
    // KStateGibbs is the classical reference for every cardinality — on
    // the k=2 evidence chain it degenerates to sequential binary Gibbs
    for (name, samples) in KSTATE_SCENARIOS {
        let s = scenarios::by_name(name);
        let mut p = ClassicalPath::new(Box::new(KStateGibbs::new(&s.graph)), 0x5E06);
        check_kstate(&mut p, &s, samples, name);
    }
}

#[test]
fn lane_engine_kstate_and_evidence_pass_gates_across_kernels_and_pools() {
    // the tentpole claim end to end: bit-plane sweeps target the right
    // (conditional) law on every kernel, with and without a pool
    for (name, samples) in KSTATE_SCENARIOS {
        let s = scenarios::by_name(name);
        for kernel in [KernelKind::Scalar, KernelKind::Tiled] {
            for pool_threads in [0usize, 4] {
                let pool = (pool_threads > 0).then(|| Arc::new(ThreadPool::new(pool_threads)));
                let mut p = LanePath::new(
                    s.graph.clone(),
                    EngineConfig { lanes: 64, seed: 0xEA, kernel, ..EngineConfig::default() },
                    pool,
                );
                let label = format!("{name}/{}-pool{pool_threads}", kernel.name());
                check_kstate(&mut p, &s, samples.max(16_384), &label);
            }
        }
    }
}

#[test]
fn ensemble_and_coordinator_kstate_evidence_pass_marginal_gates() {
    // the serving paths expose pooled marginals only: the harness runs
    // the flattened n·(k−1) marginal z-gate against exact enumeration,
    // with the deterministic evidence entries required to match exactly
    for (name, samples) in KSTATE_SCENARIOS {
        let s = scenarios::by_name(name);
        let mut p = EnsemblePath::new(s.graph.clone(), 16, 0xE3, None);
        check_kstate(&mut p, &s, samples.max(16_384), &format!("{name}/ensemble"));
        let mut p = CoordinatorPath::new(s.graph.clone(), 2, 0, 8, 0xC3);
        check_kstate(&mut p, &s, samples, &format!("{name}/coordinator"));
    }
}

// -- K-state × policy: minibatch and blocked sweeps on Potts scenarios ------

/// The K-state hub stars' subsampling policy: threshold 3 plans every
/// star hub down to the degree-4 `potts8-hub5` one, and the λ floor +
/// θ-stride mirror the binary hub policy so the acceptance correction
/// carries the burden per state plane.
fn kstate_minibatch_policy() -> SweepPolicy {
    SweepPolicy::Minibatch(MinibatchPolicy {
        degree_threshold: 3,
        lambda_scale: 0.25,
        lambda_min: 1.0,
        theta_stride: 2,
    })
}

#[test]
fn minibatch_kstate_lane_paths_pass_gates_across_kernels_and_pools() {
    // the lifted rejection, gated: per-state corrected fields feeding the
    // categorical draw must target the right (conditional) law for every
    // bit-plane count — k ∈ {3, 5, 8} hub stars, per kernel × pool
    // {0, 4}; potts5-hub6 holds leaf evidence, so the minibatch policy
    // also clears a `validate_conditioned` gate
    for name in [
        "potts3-hub9-minibatch",
        "potts5-hub6-minibatch",
        "potts8-hub5-minibatch",
    ] {
        let mut s = scenarios::by_name(name);
        // potts3-hub9 carries churn for the dedicated churn gate below;
        // here every cardinality is gated statically on its base graph
        s.churn.clear();
        for kernel in [KernelKind::Scalar, KernelKind::Tiled] {
            for pool_threads in [0usize, 4] {
                let pool = (pool_threads > 0).then(|| Arc::new(ThreadPool::new(pool_threads)));
                let mut p = LanePath::new(
                    s.graph.clone(),
                    EngineConfig {
                        lanes: 64,
                        seed: 0xB3,
                        kernel,
                        sweep: kstate_minibatch_policy(),
                    },
                    pool,
                );
                let m = p.engine().model();
                assert!(m.mb_plan(0).is_some(), "{name}: the hub must sweep minibatched");
                assert!(m.mb_plan(2).is_none(), "{name}: low-degree leaves stay exact");
                let label = format!("{name}/{}-pool{pool_threads}", kernel.name());
                check_kstate(&mut p, &s, 16_384, &label);
            }
        }
    }
}

#[test]
fn minibatch_kstate_lane_paths_stay_exact_through_hub_churn() {
    // K-state plan invalidation under the gates: drop a hub edge, re-add
    // it sign-flipped, couple two leaves — the rebuilt per-state plan
    // must still pass against the final graph
    let s = scenarios::by_name("potts3-hub9-minibatch");
    for kernel in [KernelKind::Scalar, KernelKind::Tiled] {
        let mut p = LanePath::new(
            s.graph.clone(),
            EngineConfig { lanes: 64, seed: 0xB4, kernel, sweep: kstate_minibatch_policy() },
            None,
        );
        check_churn(&mut p, &s, 16_384);
        assert!(
            p.engine().model().mb_plan(0).is_some(),
            "hub plan must survive churn (degree is unchanged)"
        );
    }
}

#[test]
fn blocked_kstate_lane_paths_pass_gates_across_kernels_and_pools() {
    // the other lifted rejection, gated: K-state FFBS tree draws
    // (k-vector upward messages, categorical root/downward draws) must
    // target the right (conditional) law above the critical coupling —
    // k ∈ {3, 5, 8}, per kernel × pool {0, 4}; potts8-chain5 clamps an
    // endpoint, so the blocked policy also clears a
    // `validate_conditioned` gate with the evidence site dropped from
    // the planner's candidate set
    for (name, samples) in [
        ("potts3-grid3x3-above", 8192),
        ("potts5-grid2x3-above", 8192),
        ("potts8-chain5-above", 8192),
    ] {
        let s = scenarios::by_name(name);
        for kernel in [KernelKind::Scalar, KernelKind::Tiled] {
            for pool_threads in [0usize, 4] {
                let pool = (pool_threads > 0).then(|| Arc::new(ThreadPool::new(pool_threads)));
                let mut p = LanePath::new(
                    s.graph.clone(),
                    EngineConfig { lanes: 64, seed: 0xD3, kernel, sweep: blocked_policy() },
                    pool,
                );
                let label = format!("{name}/{}-pool{pool_threads}", kernel.name());
                check_kstate(&mut p, &s, samples, &label);
                assert!(
                    p.engine().block_summary().0 >= 1,
                    "{label}: the above-critical model must actually grow blocks"
                );
                if let Some(plan) = p.engine().block_plan() {
                    for &(v, _) in &s.evidence {
                        assert!(
                            plan.blocks.iter().all(|b| b.nodes.iter().all(|n| n.v as usize != v)),
                            "{label}: evidence site {v} entered a block"
                        );
                    }
                }
            }
        }
    }
}

// -- gate calibration and power ---------------------------------------------

#[test]
fn exact_forward_draws_calibrate_the_gates_on_every_scenario() {
    // ground-truth iid draws must pass every gate on the whole zoo; a
    // failure here means the thresholds are mis-derived, independent of
    // any sampler
    for (i, s) in scenarios::zoo().iter().enumerate() {
        let g = s.final_graph();
        let mut fwd = ExactForward::new(&g, 0xF0 + i as u64);
        // scale iid draws with the state space (k^n, not 2^n) so every
        // chi-square bucket clears the pooling floor even on the densest
        // models
        let samples = (16 * g.k().pow(g.num_vars() as u32)).max(8192);
        let cfg = GateConfig { burn_in: 0, samples, tau: 1, ..GateConfig::default() };
        let r = validate(&mut fwd, &g, s.name, &cfg);
        println!("{}", r.summary());
        r.assert_passed();
        assert!(
            r.tv.is_some() && r.chi2.is_some(),
            "{}: joint gates must have run",
            s.name
        );
        // evidence scenarios additionally calibrate the conditional
        // gates: iid draws from the exact conditional must pass them
        if !s.evidence.is_empty() {
            let mut fwd = ExactForward::conditioned(&g, &s.evidence, 0x1F0 + i as u64);
            let name = format!("{}/conditioned", s.name);
            let r = validate_conditioned(&mut fwd, &g, &s.evidence, &name, &cfg);
            println!("{}", r.summary());
            r.assert_passed();
        }
    }
}

#[test]
fn gates_reject_a_marginal_bias() {
    // a sampler whose every marginal log-odds drifts by 0.5 must be
    // caught by the z-gate (this is the "wrong conditional table" class)
    let s = scenarios::by_name("grid3x3-below");
    let mut fwd = ExactForward::tilted(&s.graph, 0xBAD1, 0.5);
    let cfg = GateConfig { burn_in: 0, samples: 8192, tau: 1, ..GateConfig::default() };
    let r = validate(&mut fwd, &s.graph, "grid3x3-below/tilted", &cfg);
    println!("{}", r.summary());
    assert!(!r.passed(), "biased sampler slipped through");
    assert!(!r.max_z.passed(), "the marginal z-gate must fire");
}

#[test]
fn gates_reject_a_joint_bias_that_marginals_cannot_see() {
    // a parity tilt reshapes the joint while moving each marginal by
    // < 0.005 — only the joint TV/chi-square gates can catch it (this is
    // the "correlations wrong, marginals fine" class, e.g. a swapped
    // endpoint pair)
    let s = scenarios::by_name("grid3x3-below");
    let mut fwd = ExactForward::parity_tilted(&s.graph, 0xBAD2, 0.6);
    let cfg = GateConfig { burn_in: 0, samples: 8192, tau: 1, ..GateConfig::default() };
    let r = validate(&mut fwd, &s.graph, "grid3x3-below/parity", &cfg);
    println!("{}", r.summary());
    assert!(!r.passed(), "joint-only bias slipped through");
    assert!(
        r.max_z.passed(),
        "marginals alone must NOT see this bias (max_z {:.2})",
        r.max_z.stat
    );
    let chi2_failed = r.chi2.as_ref().is_some_and(|(g, _)| !g.passed());
    let tv_failed = r.tv.as_ref().is_some_and(|g| !g.passed());
    assert!(chi2_failed || tv_failed, "a joint gate must fire");
}
