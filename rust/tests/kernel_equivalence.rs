//! Kernel equivalence: every [`KernelKind`] must sample the exact same
//! trajectory — packed x and θ words bit-equal after every sweep — for
//! every lane count (especially counts that are *not* multiples of the
//! 8-lane tile width or of the 64-lane word, exercising tail masking),
//! with and without a thread pool, and across mid-run churn.
//!
//! This is the contract that makes the kernel choice a pure performance
//! knob: `scalar` is the readable reference, `tiled` (and `nightly-simd`
//! when compiled in) must be indistinguishable from it except in wall
//! clock. CI runs this file in release mode, where the tiled bodies
//! actually vectorize.

use std::sync::Arc;

use pdgibbs::duality::{BlockPolicy, MinibatchPolicy};
use pdgibbs::engine::{EngineConfig, KernelKind, LanePdSampler, SweepPolicy};
use pdgibbs::graph::{FactorGraph, PairFactor};
use pdgibbs::util::proptest::{check, Gen};
use pdgibbs::util::ThreadPool;
use pdgibbs::workloads;

/// A graph that exercises BOTH x-half-step paths at once: grid variables
/// (degree ≤ 4) draw from cached tables, the appended hub (degree 9 > the
/// degree-6 cache cap) takes the per-lane log-odds accumulate fallback.
/// Mixed-sign couplings cover the Lemma-4 β < 0 branch.
fn mixed_path_graph() -> FactorGraph {
    let mut g = workloads::ising_grid(3, 3, 0.35, 0.1);
    let hub = g.add_var(0.2);
    for (i, v) in (0..9).enumerate() {
        let beta = if i % 2 == 0 { 0.3 } else { -0.25 };
        g.add_factor(PairFactor::ising(hub, v, beta));
    }
    g
}

/// Run `sweeps` sweeps on one engine per kernel and assert the packed
/// states never diverge. `pool_sizes[i]` attaches a pool to engine `i`
/// (0 = serial), proving pooling × kernel choice is also trajectory-free.
fn assert_equivalent(
    g: &FactorGraph,
    lanes: usize,
    sweeps: usize,
    kernels: &[(KernelKind, usize)],
) {
    let mut engines: Vec<LanePdSampler> = kernels
        .iter()
        .map(|&(kernel, pool)| {
            let eng = LanePdSampler::with_config(
                g,
                EngineConfig {
                    lanes,
                    seed: 0xA5A5,
                    kernel,
                    ..EngineConfig::default()
                },
            );
            if pool > 0 {
                eng.with_pool(Arc::new(ThreadPool::new(pool)))
            } else {
                eng
            }
        })
        .collect();
    for sweep in 0..sweeps {
        for eng in engines.iter_mut() {
            eng.sweep();
        }
        let (first, rest) = engines.split_first().unwrap();
        for eng in rest {
            assert_eq!(
                first.state_words(),
                eng.state_words(),
                "x diverged at sweep {sweep}, lanes {lanes}: {} vs {}",
                first.kernel().name(),
                eng.kernel().name()
            );
            assert_eq!(
                first.theta_words(),
                eng.theta_words(),
                "theta diverged at sweep {sweep}, lanes {lanes}: {} vs {}",
                first.kernel().name(),
                eng.kernel().name()
            );
        }
    }
}

/// Every compiled-in kernel, serial.
fn all_serial() -> Vec<(KernelKind, usize)> {
    KernelKind::all().iter().map(|&k| (k, 0)).collect()
}

#[test]
fn kernels_bit_identical_across_awkward_lane_counts() {
    // deliberately not multiples of the tile width (8) or the word (64):
    // every tail-masking edge case from a single partial tile to a full
    // word plus one lane
    let g = mixed_path_graph();
    for &lanes in &[1usize, 3, 7, 9, 13, 63, 65, 70, 100, 127, 129] {
        assert_equivalent(&g, lanes, 15, &all_serial());
    }
}

#[test]
fn kernels_bit_identical_at_word_multiples() {
    let g = mixed_path_graph();
    for &lanes in &[8usize, 64, 128, 192] {
        assert_equivalent(&g, lanes, 15, &all_serial());
    }
}

#[test]
fn tiled_pooled_matches_scalar_serial() {
    // kernel choice x pool size: all four combinations, one trajectory
    let g = mixed_path_graph();
    let combos = [
        (KernelKind::Scalar, 0usize),
        (KernelKind::Scalar, 3),
        (KernelKind::Tiled, 0),
        (KernelKind::Tiled, 5),
    ];
    assert_equivalent(&g, 70, 30, &combos);
}

#[test]
fn kernels_bit_identical_under_churn() {
    // add/remove factors mid-run on every engine in lockstep: the cached
    // x-tables relocate inside the tile-aligned arena, the CSR overlay
    // fills, slots die and are reused — trajectories must stay equal;
    // 90 lanes = one full word + a 26-lane tail
    let mut g = workloads::ising_grid(3, 4, 0.3, 0.05);
    let mut engines: Vec<LanePdSampler> = KernelKind::all()
        .iter()
        .map(|&k| LanePdSampler::new(&g, 90, 77).with_kernel(k))
        .collect();
    let compare = |engines: &[LanePdSampler], stage: &str| {
        let (first, rest) = engines.split_first().unwrap();
        for eng in rest {
            assert_eq!(first.state_words(), eng.state_words(), "x diverged {stage}");
            assert_eq!(first.theta_words(), eng.theta_words(), "θ diverged {stage}");
        }
    };
    for _ in 0..10 {
        engines.iter_mut().for_each(LanePdSampler::sweep);
    }
    compare(&engines, "before churn");
    // grow a grid variable past the degree-6 cache cap (table → fallback)
    let mut added = Vec::new();
    for v in [5usize, 7, 8, 9, 10] {
        let id = g.add_factor(PairFactor::ising(0, v, -0.2));
        added.push(id);
        for eng in engines.iter_mut() {
            eng.add_factor(id, g.factor(id).unwrap());
        }
    }
    for _ in 0..10 {
        engines.iter_mut().for_each(LanePdSampler::sweep);
    }
    compare(&engines, "after inserts");
    // shrink it back under the cap (fallback → freshly rebuilt table)
    for id in added {
        g.remove_factor(id).unwrap();
        for eng in engines.iter_mut() {
            assert!(eng.remove_factor(id));
        }
    }
    for _ in 0..10 {
        engines.iter_mut().for_each(LanePdSampler::sweep);
    }
    compare(&engines, "after removals");
}

/// Blocked-policy mirror of [`assert_equivalent`]: jointly-drawn tree
/// blocks (forward-filter/backward-sample, no kernel primitives) must
/// not break kernel × pool bit-identity — including while plans form
/// and re-form mid-run. Returns the final block count so callers can
/// additionally assert the plan actually engaged.
fn assert_equivalent_blocked(
    g: &FactorGraph,
    lanes: usize,
    sweeps: usize,
    kernels: &[(KernelKind, usize)],
) -> usize {
    let mut engines: Vec<LanePdSampler> = kernels
        .iter()
        .map(|&(kernel, pool)| {
            let eng = LanePdSampler::with_config(
                g,
                EngineConfig {
                    lanes,
                    seed: 0xB10C,
                    kernel,
                    sweep: SweepPolicy::Blocked(BlockPolicy { cap: 4, epoch: 4 }),
                },
            );
            if pool > 0 {
                eng.with_pool(Arc::new(ThreadPool::new(pool)))
            } else {
                eng
            }
        })
        .collect();
    for sweep in 0..sweeps {
        for eng in engines.iter_mut() {
            eng.sweep();
        }
        let (first, rest) = engines.split_first().unwrap();
        for eng in rest {
            assert_eq!(
                first.state_words(),
                eng.state_words(),
                "blocked x diverged at sweep {sweep}, lanes {lanes}: {} vs {}",
                first.kernel().name(),
                eng.kernel().name()
            );
            assert_eq!(
                first.theta_words(),
                eng.theta_words(),
                "blocked theta diverged at sweep {sweep}, lanes {lanes}: {} vs {}",
                first.kernel().name(),
                eng.kernel().name()
            );
        }
    }
    engines[0].block_summary().0
}

#[test]
fn blocked_kernels_bit_identical_across_awkward_lane_counts() {
    // β = 0.8 ensures the agreement EWMAs actually grow blocks; lane
    // counts cover the same tail-masking edge cases as the flat tests
    let g = workloads::ising_grid(3, 3, 0.8, 0.05);
    let combos: Vec<(KernelKind, usize)> =
        KernelKind::all().iter().map(|&k| (k, 0)).collect();
    for &lanes in &[1usize, 7, 63, 65, 90] {
        let blocks = assert_equivalent_blocked(&g, lanes, 30, &combos);
        if lanes >= 7 {
            assert!(blocks >= 1, "lanes {lanes}: plan never engaged");
        }
    }
}

#[test]
fn blocked_tiled_pooled_matches_scalar_serial() {
    // kernel choice × pool size under the blocked policy: the pooled
    // runs partition work by sweep *units* (blocks + singletons), a
    // different chunking than the flat per-variable bounds
    let g = workloads::ising_grid(3, 4, 0.8, 0.05);
    let combos = [
        (KernelKind::Scalar, 0usize),
        (KernelKind::Scalar, 3),
        (KernelKind::Tiled, 0),
        (KernelKind::Tiled, 5),
    ];
    let blocks = assert_equivalent_blocked(&g, 70, 30, &combos);
    assert!(blocks >= 1, "plan never engaged");
}

#[test]
fn blocked_kernels_bit_identical_under_churn() {
    // churn while blocks are live: tree slots die (eager re-plan),
    // recycled slots restart neutral, the hub crosses the table-cache
    // cap — trajectories must stay equal through all of it
    let mut g = workloads::ising_grid(3, 4, 0.8, 0.05);
    let cfg = |kernel| EngineConfig {
        lanes: 90,
        seed: 77,
        kernel,
        sweep: SweepPolicy::Blocked(BlockPolicy { cap: 4, epoch: 4 }),
    };
    let mut engines: Vec<LanePdSampler> = KernelKind::all()
        .iter()
        .map(|&k| LanePdSampler::with_config(&g, cfg(k)))
        .collect();
    let compare = |engines: &[LanePdSampler], stage: &str| {
        let (first, rest) = engines.split_first().unwrap();
        for eng in rest {
            assert_eq!(first.state_words(), eng.state_words(), "x diverged {stage}");
            assert_eq!(first.theta_words(), eng.theta_words(), "θ diverged {stage}");
        }
    };
    for _ in 0..20 {
        engines.iter_mut().for_each(LanePdSampler::sweep);
    }
    compare(&engines, "before churn");
    assert!(engines[0].block_summary().0 >= 1, "plan must be live pre-churn");
    let mut added = Vec::new();
    for v in [5usize, 7, 8, 9, 10] {
        let id = g.add_factor(PairFactor::ising(0, v, -0.2));
        added.push(id);
        for eng in engines.iter_mut() {
            eng.add_factor(id, g.factor(id).unwrap());
        }
    }
    for _ in 0..10 {
        engines.iter_mut().for_each(LanePdSampler::sweep);
    }
    compare(&engines, "after inserts");
    for id in added {
        g.remove_factor(id).unwrap();
        for eng in engines.iter_mut() {
            assert!(eng.remove_factor(id));
        }
    }
    for _ in 0..10 {
        engines.iter_mut().for_each(LanePdSampler::sweep);
    }
    compare(&engines, "after removals");
}

#[test]
fn tiled_keeps_ghost_lanes_zero() {
    // 69 lanes: 5-lane tail in word 1 — stale tiled scratch must never
    // leak past the mask into the packed state
    let g = mixed_path_graph();
    for &kernel in KernelKind::all() {
        let mut eng = LanePdSampler::new(&g, 69, 12).with_kernel(kernel);
        for _ in 0..40 {
            eng.sweep();
        }
        let ghost = !((1u64 << 5) - 1); // lanes 5..64 of the tail word
        for (i, &w) in eng.state_words().iter().chain(eng.theta_words()).enumerate() {
            if i % 2 == 1 {
                assert_eq!(w & ghost, 0, "{}: ghost lanes in word {i}", kernel.name());
            }
        }
    }
}

/// K-state mirror of [`mixed_path_graph`]: a Potts grid (cached x-tables)
/// plus an appended hub past the degree-6 cache cap (per-lane score
/// fallback), with mixed-sign couplings. K-state graphs carry no unary
/// fields, so the hub is added neutral.
fn mixed_path_potts(k: usize) -> FactorGraph {
    let mut g = workloads::potts_grid(3, 3, k, 0.35);
    let hub = g.add_var(0.0);
    for (i, v) in (0..9).enumerate() {
        let beta = if i % 2 == 0 { 0.3 } else { -0.25 };
        g.add_factor(PairFactor::potts(hub, v, beta));
    }
    g
}

#[test]
fn kstate_kernels_bit_identical_across_lane_counts_and_bit_planes() {
    // one cardinality per bit-plane count b ∈ {1, 2, 3}, plus the k that
    // exactly fills each plane budget; the lane sweep reuses the binary
    // suite's tail-masking edge cases (partial tile, word ± 1, two words
    // plus one)
    for &(k, planes) in &[(2usize, 1usize), (3, 2), (4, 2), (5, 3), (8, 3)] {
        let g = mixed_path_potts(k);
        let probe = LanePdSampler::new(&g, 1, 0);
        assert_eq!(probe.k(), k);
        assert_eq!(probe.bit_planes(), planes, "k={k}: wrong plane count");
        for &lanes in &[1usize, 7, 63, 65, 127, 129] {
            assert_equivalent(&g, lanes, 10, &all_serial());
        }
    }
}

#[test]
fn kstate_tiled_pooled_matches_scalar_serial() {
    // kernel × pool under 3 bit-planes: the pooled runs chunk per-variable
    // work that now spans multiple x-planes per site
    let g = mixed_path_potts(5);
    let combos = [
        (KernelKind::Scalar, 0usize),
        (KernelKind::Scalar, 3),
        (KernelKind::Tiled, 0),
        (KernelKind::Tiled, 5),
    ];
    assert_equivalent(&g, 70, 20, &combos);
}

#[test]
fn kstate_kernels_bit_identical_under_churn_and_clamping() {
    // k = 3 grid churned past the degree-6 cache cap while a site holds
    // evidence: trajectories must stay equal across kernels AND the
    // clamped site must never move in any lane through inserts, removals,
    // and the table ↔ fallback transitions they trigger
    let mut g = workloads::potts_grid(3, 4, 3, 0.3);
    let mut engines: Vec<LanePdSampler> = KernelKind::all()
        .iter()
        .map(|&k| LanePdSampler::new(&g, 90, 77).with_kernel(k))
        .collect();
    for eng in engines.iter_mut() {
        eng.clamp(3, 2).unwrap();
    }
    let compare = |engines: &[LanePdSampler], stage: &str| {
        let (first, rest) = engines.split_first().unwrap();
        for eng in rest {
            assert_eq!(first.state_words(), eng.state_words(), "x diverged {stage}");
            assert_eq!(first.theta_words(), eng.theta_words(), "θ diverged {stage}");
        }
        for eng in engines {
            for lane in [0usize, 63, 64, 89] {
                assert_eq!(eng.lane_value(3, lane), 2, "evidence moved {stage}");
            }
        }
    };
    for _ in 0..10 {
        engines.iter_mut().for_each(LanePdSampler::sweep);
    }
    compare(&engines, "before churn");
    // grow var 0 (grid degree 2) to degree 7 — past the cache cap
    let mut added = Vec::new();
    for v in [5usize, 7, 8, 9, 10] {
        let id = g.add_factor(PairFactor::potts(0, v, -0.2));
        added.push(id);
        for eng in engines.iter_mut() {
            eng.add_factor(id, g.factor(id).unwrap());
        }
    }
    for _ in 0..10 {
        engines.iter_mut().for_each(LanePdSampler::sweep);
    }
    compare(&engines, "after inserts");
    for id in added {
        g.remove_factor(id).unwrap();
        for eng in engines.iter_mut() {
            assert!(eng.remove_factor(id));
        }
    }
    for _ in 0..10 {
        engines.iter_mut().for_each(LanePdSampler::sweep);
    }
    compare(&engines, "after removals");
}

/// Policy-parameterized mirror of [`assert_equivalent`]: same lockstep
/// bit-identity contract, but every engine runs under `sweep` instead of
/// the Exact default. Used by the minibatch × K suites below.
fn assert_equivalent_policy(
    g: &FactorGraph,
    lanes: usize,
    sweeps: usize,
    kernels: &[(KernelKind, usize)],
    sweep_policy: SweepPolicy,
) {
    let mut engines: Vec<LanePdSampler> = kernels
        .iter()
        .map(|&(kernel, pool)| {
            let eng = LanePdSampler::with_config(
                g,
                EngineConfig { lanes, seed: 0xA5A5, kernel, sweep: sweep_policy },
            );
            if pool > 0 {
                eng.with_pool(Arc::new(ThreadPool::new(pool)))
            } else {
                eng
            }
        })
        .collect();
    for sweep in 0..sweeps {
        for eng in engines.iter_mut() {
            eng.sweep();
        }
        let (first, rest) = engines.split_first().unwrap();
        for eng in rest {
            assert_eq!(
                first.state_words(),
                eng.state_words(),
                "x diverged at sweep {sweep}, lanes {lanes}: {} vs {}",
                first.kernel().name(),
                eng.kernel().name()
            );
            assert_eq!(
                first.theta_words(),
                eng.theta_words(),
                "theta diverged at sweep {sweep}, lanes {lanes}: {} vs {}",
                first.kernel().name(),
                eng.kernel().name()
            );
        }
    }
}

/// A minibatch policy the 9-degree hub of [`mixed_path_potts`] actually
/// crosses: threshold 4 plans the hub, stride 2 keeps θ refreshes dense
/// enough that thinning correctness shows up within a short run.
fn mb4() -> SweepPolicy {
    SweepPolicy::Minibatch(MinibatchPolicy {
        degree_threshold: 4,
        lambda_scale: 1.0,
        lambda_min: 4.0,
        theta_stride: 2,
    })
}

#[test]
fn minibatch_kstate_kernels_bit_identical_across_lane_counts() {
    // per-state thinned fields feed a categorical draw: the Poisson event
    // loop and the plane-packed writeback must mask tails identically in
    // every kernel, for every bit-plane count b ∈ {2, 3}
    for &k in &[3usize, 5, 8] {
        let g = mixed_path_potts(k);
        let probe = LanePdSampler::with_config(
            &g,
            EngineConfig { lanes: 1, seed: 0, kernel: KernelKind::default(), sweep: mb4() },
        );
        assert!(
            probe.model().mb_plan(9).is_some(),
            "k={k}: the hub must carry a minibatch plan"
        );
        for &lanes in &[1usize, 63, 65, 129] {
            assert_equivalent_policy(&g, lanes, 10, &all_serial(), mb4());
        }
    }
}

#[test]
fn minibatch_kstate_tiled_pooled_matches_scalar_serial() {
    // kernel × pool under thinned K-state updates: pooled runs chunk
    // per-variable bounds while the hub's Poisson/thinning stream must
    // stay keyed by (sweep, site) alone
    let g = mixed_path_potts(5);
    let combos = [
        (KernelKind::Scalar, 0usize),
        (KernelKind::Scalar, 4),
        (KernelKind::Tiled, 0),
        (KernelKind::Tiled, 4),
    ];
    assert_equivalent_policy(&g, 65, 15, &combos, mb4());
}

#[test]
fn minibatch_kstate_kernels_bit_identical_under_churn_and_clamping() {
    // churn drives var 0 across the degree threshold AND the table-cache
    // cap, so minibatch plans appear then vanish mid-run while a clamped
    // site holds evidence — trajectories must stay equal throughout and
    // the evidence must never move
    let mut g = workloads::potts_grid(3, 4, 3, 0.3);
    let cfg = |kernel| EngineConfig { lanes: 90, seed: 77, kernel, sweep: mb4() };
    let mut engines: Vec<LanePdSampler> = KernelKind::all()
        .iter()
        .map(|&k| LanePdSampler::with_config(&g, cfg(k)))
        .collect();
    for eng in engines.iter_mut() {
        eng.clamp(3, 2).unwrap();
    }
    assert!(engines[0].model().mb_plan(0).is_none(), "grid degrees sit under the threshold");
    let compare = |engines: &[LanePdSampler], stage: &str| {
        let (first, rest) = engines.split_first().unwrap();
        for eng in rest {
            assert_eq!(first.state_words(), eng.state_words(), "x diverged {stage}");
            assert_eq!(first.theta_words(), eng.theta_words(), "θ diverged {stage}");
        }
        for eng in engines {
            for lane in [0usize, 63, 64, 89] {
                assert_eq!(eng.lane_value(3, lane), 2, "evidence moved {stage}");
            }
        }
    };
    for _ in 0..10 {
        engines.iter_mut().for_each(LanePdSampler::sweep);
    }
    compare(&engines, "before churn");
    // grow var 0 (grid degree 2) to degree 7: plan forms, cache cap crossed
    let mut added = Vec::new();
    for v in [5usize, 7, 8, 9, 10] {
        let id = g.add_factor(PairFactor::potts(0, v, -0.2));
        added.push(id);
        for eng in engines.iter_mut() {
            eng.add_factor(id, g.factor(id).unwrap());
        }
    }
    assert!(engines[0].model().mb_plan(0).is_some(), "degree 7 must be planned");
    for _ in 0..10 {
        engines.iter_mut().for_each(LanePdSampler::sweep);
    }
    compare(&engines, "after inserts");
    for id in added {
        g.remove_factor(id).unwrap();
        for eng in engines.iter_mut() {
            assert!(eng.remove_factor(id));
        }
    }
    assert!(engines[0].model().mb_plan(0).is_none(), "plan must retire with the degree");
    for _ in 0..10 {
        engines.iter_mut().for_each(LanePdSampler::sweep);
    }
    compare(&engines, "after removals");
}

#[test]
fn blocked_kstate_kernels_bit_identical_across_lane_counts() {
    // K-state FFBS blocks: k-vector upward messages and categorical
    // root/downward draws replace the binary bernoulli path, but the
    // kernel choice must stay invisible — and low chance agreement
    // (≈ 1/k) means the agreement EWMAs engage blocks readily
    for &k in &[3usize, 5, 8] {
        let g = workloads::potts_grid(3, 3, k, 0.8);
        for &lanes in &[1usize, 63, 65, 129] {
            let blocks = assert_equivalent_blocked(&g, lanes, 20, &all_serial());
            if lanes >= 7 {
                assert!(blocks >= 1, "k={k} lanes {lanes}: plan never engaged");
            }
        }
    }
}

#[test]
fn blocked_kstate_tiled_pooled_matches_scalar_serial() {
    // kernel × pool with jointly-drawn K-state tree blocks: pooled runs
    // partition by sweep units, and every block draw consumes exactly one
    // uniform per node per lane regardless of kernel
    let g = workloads::potts_grid(3, 4, 5, 0.8);
    let combos = [
        (KernelKind::Scalar, 0usize),
        (KernelKind::Scalar, 4),
        (KernelKind::Tiled, 0),
        (KernelKind::Tiled, 4),
    ];
    let blocks = assert_equivalent_blocked(&g, 65, 25, &combos);
    assert!(blocks >= 1, "plan never engaged");
}

#[test]
fn blocked_kstate_kernels_bit_identical_under_churn_and_clamping() {
    // churn while K-state blocks are live, with evidence held: tree slots
    // die (eager re-plan), the clamped site leaves the candidate set, and
    // the hub crosses the table-cache cap — all kernels in lockstep
    let mut g = workloads::potts_grid(3, 4, 3, 0.8);
    let cfg = |kernel| EngineConfig {
        lanes: 90,
        seed: 77,
        kernel,
        sweep: SweepPolicy::Blocked(BlockPolicy { cap: 4, epoch: 4 }),
    };
    let mut engines: Vec<LanePdSampler> = KernelKind::all()
        .iter()
        .map(|&k| LanePdSampler::with_config(&g, cfg(k)))
        .collect();
    for eng in engines.iter_mut() {
        eng.clamp(3, 2).unwrap();
    }
    let compare = |engines: &[LanePdSampler], stage: &str| {
        let (first, rest) = engines.split_first().unwrap();
        for eng in rest {
            assert_eq!(first.state_words(), eng.state_words(), "x diverged {stage}");
            assert_eq!(first.theta_words(), eng.theta_words(), "θ diverged {stage}");
        }
        for eng in engines {
            for lane in [0usize, 63, 64, 89] {
                assert_eq!(eng.lane_value(3, lane), 2, "evidence moved {stage}");
            }
            assert!(
                eng.block_plan().map_or(true, |p| p
                    .blocks
                    .iter()
                    .all(|b| b.nodes.iter().all(|n| n.v != 3))),
                "clamped site entered a block {stage}"
            );
        }
    };
    for _ in 0..20 {
        engines.iter_mut().for_each(LanePdSampler::sweep);
    }
    compare(&engines, "before churn");
    assert!(engines[0].block_summary().0 >= 1, "plan must be live pre-churn");
    let mut added = Vec::new();
    for v in [5usize, 7, 8, 9, 10] {
        let id = g.add_factor(PairFactor::potts(0, v, -0.2));
        added.push(id);
        for eng in engines.iter_mut() {
            eng.add_factor(id, g.factor(id).unwrap());
        }
    }
    for _ in 0..10 {
        engines.iter_mut().for_each(LanePdSampler::sweep);
    }
    compare(&engines, "after inserts");
    for id in added {
        g.remove_factor(id).unwrap();
        for eng in engines.iter_mut() {
            assert!(eng.remove_factor(id));
        }
    }
    for _ in 0..10 {
        engines.iter_mut().for_each(LanePdSampler::sweep);
    }
    compare(&engines, "after removals");
}

#[test]
fn k2_trajectories_pinned_across_construction_paths() {
    // the K-state generalization must be layout-invisible at k = 2: a
    // graph built through the pre-existing binary constructor and one
    // built through `new_k(n, 2)` with identical topology drive engines
    // whose packed words agree sweep for sweep, in the single-plane
    // binary layout (one x-plane, one θ-plane, `n · words` rows)
    let gb = mixed_path_graph();
    let mut gk = FactorGraph::new_k(9, 2);
    for v in 0..9 {
        gk.set_unary(v, gb.unary(v));
    }
    let hub = gk.add_var(gb.unary(9));
    assert_eq!(hub, 9);
    // replay the binary graph's factors in slot order (no removals, so
    // ids are dense)
    for id in 0..gb.num_factors() {
        gk.add_factor(gb.factor(id).unwrap().clone());
    }
    for &lanes in &[1usize, 65, 129] {
        let words = lanes.div_ceil(64);
        let mut binary = LanePdSampler::new(&gb, lanes, 0x2B1D);
        let mut kstate = LanePdSampler::new(&gk, lanes, 0x2B1D);
        assert_eq!(kstate.k(), 2);
        assert_eq!(kstate.bit_planes(), 1);
        assert_eq!(kstate.theta_planes(), 1);
        assert_eq!(kstate.state_words().len(), 10 * words);
        for sweep in 0..20 {
            binary.sweep();
            kstate.sweep();
            assert_eq!(
                binary.state_words(),
                kstate.state_words(),
                "k=2 x diverged from the binary layout at sweep {sweep}, lanes {lanes}"
            );
            assert_eq!(
                binary.theta_words(),
                kstate.theta_words(),
                "k=2 θ diverged from the binary layout at sweep {sweep}, lanes {lanes}"
            );
        }
    }
}

#[test]
fn prop_kernel_equivalence_random_graphs_lanes_and_churn() {
    check("scalar ≡ tiled on random models", 12, |gn: &mut Gen| {
        let n = gn.usize_in(2..=7);
        let mut g = FactorGraph::new(n);
        for v in 0..n {
            g.set_unary(v, gn.f64_in(-0.8, 0.8));
        }
        let factors = gn.usize_in(1..=9);
        for _ in 0..factors {
            let v1 = gn.usize_in(0..=n - 1);
            let mut v2 = gn.usize_in(0..=n - 1);
            if v1 == v2 {
                v2 = (v2 + 1) % n;
            }
            g.add_factor(PairFactor::new(v1, v2, gn.positive_table(1.5)));
        }
        // lane count biased toward awkward tails
        let lanes = match gn.usize_in(0..=3) {
            0 => gn.usize_in(1..=7),
            1 => gn.usize_in(60..=68),
            2 => 64,
            _ => gn.usize_in(120..=140),
        };
        let seed = gn.u64();
        let mut scalar = LanePdSampler::new(&g, lanes, seed).with_kernel(KernelKind::Scalar);
        let mut tiled = LanePdSampler::new(&g, lanes, seed).with_kernel(KernelKind::Tiled);
        for sweep in 0..8 {
            // occasional lockstep churn
            if sweep == 4 {
                let v1 = gn.usize_in(0..=n - 1);
                let v2 = (v1 + 1) % n;
                let id = g.add_factor(PairFactor::new(v1, v2, gn.positive_table(1.0)));
                let f = g.factor(id).unwrap().clone();
                scalar.add_factor(id, &f);
                tiled.add_factor(id, &f);
            }
            scalar.sweep();
            tiled.sweep();
            if scalar.state_words() != tiled.state_words() {
                return Err(format!("x diverged at sweep {sweep} (lanes {lanes})"));
            }
            if scalar.theta_words() != tiled.theta_words() {
                return Err(format!("θ diverged at sweep {sweep} (lanes {lanes})"));
            }
        }
        Ok(())
    });
}
