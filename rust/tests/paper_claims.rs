//! Paper-claims smoke tests (PAPER.md §5–§6): the qualitative trade-offs
//! the paper asserts must hold in this implementation, deterministically.
//!
//! 1. "our method leads to inferior mixing times compared to a
//!    sequential Gibbs sampler" — but both target the exact stationary
//!    distribution. Checked via effective sample size of the
//!    magnetization trace (ESS ≈ sweeps / integrated autocorrelation
//!    time): sequential must hold a ≥ 2× ESS advantage (the paper
//!    reports 2–7×; seed-fixed measurement here lands ≈ 4–5×), while
//!    both samplers' marginals converge to enumeration.
//! 2. "our method can be combined with blocking to improve mixing" —
//!    tree-blocked PD (§5.4) must beat plain PD's ESS by ≥ 1.5×
//!    (measured ≈ 3×): the spanning tree is resampled by one exact joint
//!    draw per sweep, collapsing the duals' extra autocorrelation.
//! 3. The same §5.4 claim holds on the lane engine with *adaptive*
//!    blocking: `SweepPolicy::Blocked` (blocks grown from agreement
//!    EWMAs, no hand-picked tree) must beat the flat PD lane path's ESS
//!    by ≥ 1.3× on the same grid.
//!
//! Margins are half the measured effects, so these stay smoke tests of
//! the *claims*, not brittle performance assertions; the exactness side
//! is enforced much harder by `statistical_validation.rs`.

use pdgibbs::diagnostics::effective_sample_size;
use pdgibbs::duality::BlockPolicy;
use pdgibbs::engine::{EngineConfig, KernelKind, LanePdSampler, SweepPolicy};
use pdgibbs::inference::exact;
use pdgibbs::rng::Pcg64;
use pdgibbs::samplers::{BlockedPd, PdSampler, Sampler, SequentialGibbs};
use pdgibbs::workloads;

struct RunStats {
    ess: f64,
    marginals: Vec<f64>,
}

/// Burn in, then trace magnetization + per-site sums over `sweeps`.
fn run_stats(sampler: &mut dyn Sampler, seed: u64, burn: usize, sweeps: usize) -> RunStats {
    let mut rng = Pcg64::seed(seed);
    for _ in 0..burn {
        sampler.sweep(&mut rng);
    }
    let n = sampler.state().len();
    let mut sums = vec![0.0f64; n];
    let mut mag = Vec::with_capacity(sweeps);
    for _ in 0..sweeps {
        sampler.sweep(&mut rng);
        let x = sampler.state();
        let mut ones = 0.0;
        for (s, &b) in sums.iter_mut().zip(x) {
            *s += b as f64;
            ones += b as f64;
        }
        mag.push(ones / n as f64);
    }
    RunStats {
        ess: effective_sample_size(&mag),
        marginals: sums.into_iter().map(|s| s / sweeps as f64).collect(),
    }
}

fn assert_converged(name: &str, got: &[f64], want: &[f64], tol: f64) {
    for (v, (&g, &w)) in got.iter().zip(want).enumerate() {
        assert!(
            (g - w).abs() < tol,
            "{name} did not converge: var {v} {g:.4} vs exact {w:.4} (tol {tol})"
        );
    }
}

/// The claims' test bed: a 3×4 grid at β = 0.5 (above the weak-coupling
/// boundary, where the mixing gaps are pronounced) with a small field
/// breaking the up/down symmetry.
fn claims_grid() -> pdgibbs::graph::FactorGraph {
    workloads::ising_grid(3, 4, 0.5, 0.1)
}

#[test]
fn pd_converges_but_mixes_slower_than_sequential() {
    let g = claims_grid();
    let want = exact::enumerate(&g).marginals;
    let seq = run_stats(&mut SequentialGibbs::new(&g), 0xC1A1, 2000, 16_000);
    let pd = run_stats(&mut PdSampler::new(&g), 0xC1A2, 2000, 16_000);
    // both converge — the PD chain is exact, just slower (loose 4σ
    // tolerance: the hard exactness gates live in statistical_validation)
    assert_converged("sequential", &seq.marginals, &want, 0.1);
    assert_converged("primal-dual", &pd.marginals, &want, 0.1);
    // the paper's honest trade-off: sequential holds a clear ESS lead
    assert!(
        seq.ess > 2.0 * pd.ess,
        "paper claims PD mixes 2–7x slower than sequential; \
         measured seq ESS {:.0} vs pd ESS {:.0}",
        seq.ess,
        pd.ess
    );
    assert!(
        pd.ess > 50.0,
        "PD must still make progress (ess {:.1})",
        pd.ess
    );
}

#[test]
fn blocking_improves_pd_mixing() {
    let g = claims_grid();
    let want = exact::enumerate(&g).marginals;
    let pd = run_stats(&mut PdSampler::new(&g), 0xC1A3, 2000, 16_000);
    let mut blocked_sampler = BlockedPd::new(&g);
    assert!(
        blocked_sampler.tree_size() >= g.num_vars() - 1,
        "spanning tree must cover the grid"
    );
    let blocked = run_stats(&mut blocked_sampler, 0xC1A4, 2000, 16_000);
    assert_converged("blocked-pd", &blocked.marginals, &want, 0.1);
    assert!(
        blocked.ess > 1.5 * pd.ess,
        "paper claims blocking improves PD mixing; \
         measured blocked ESS {:.0} vs pd ESS {:.0}",
        blocked.ess,
        pd.ess
    );
}

/// Burn in a lane engine, then trace the lane-averaged magnetization and
/// return its ESS — the lane-engine analogue of [`run_stats`].
fn lane_ess(g: &pdgibbs::graph::FactorGraph, sweep: SweepPolicy, burn: usize, sweeps: usize) -> f64 {
    let mut eng = LanePdSampler::with_config(
        g,
        EngineConfig { lanes: 64, seed: 0xC1A5, kernel: KernelKind::default(), sweep },
    );
    for _ in 0..burn {
        eng.sweep();
    }
    let denom = (g.num_vars() * 64) as f64;
    let mut mag = Vec::with_capacity(sweeps);
    for _ in 0..sweeps {
        eng.sweep();
        let ones: u64 = eng.state_words().iter().map(|w| w.count_ones() as u64).sum();
        mag.push(ones as f64 / denom);
    }
    effective_sample_size(&mag)
}

#[test]
fn adaptive_blocking_improves_lane_pd_mixing() {
    // the §5.4 claim carried to the lane engine, with the blocks chosen
    // *adaptively* from agreement statistics instead of a hand-picked
    // spanning tree: the blocked lane path must beat the flat PD lane
    // path's ESS on the same above-critical grid (margin below the
    // bench's 1.5× ESS/s wall-clock target — this pins pure per-sweep
    // mixing, with the cost side covered by `--mode blocked`)
    let g = claims_grid();
    let flat = lane_ess(&g, SweepPolicy::Exact, 2000, 16_000);
    let blocked = lane_ess(
        &g,
        SweepPolicy::Blocked(BlockPolicy { cap: 12, epoch: 8 }),
        2000,
        16_000,
    );
    assert!(
        blocked > 1.3 * flat,
        "adaptive blocking must improve lane-PD mixing; \
         measured blocked ESS {blocked:.0} vs flat ESS {flat:.0}"
    );
    assert!(flat > 50.0, "flat lane PD must still make progress ({flat:.1})");
}
