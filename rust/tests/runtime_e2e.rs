//! End-to-end integration over the XLA runtime: the AOT artifacts (L1
//! Pallas kernel + L2 JAX scan) executed from Rust via PJRT.
//!
//! Requires `make artifacts` (the Makefile `test` target guarantees it).
//! The decisive test is `xla_chain_matches_exact_marginals`: the artifact
//! chain must converge to the same distribution as brute-force
//! enumeration of the Rust-side graph — validating python dualization ==
//! rust dualization == HLO semantics == PJRT execution in one shot.

// The PJRT runtime only exists under `--features xla` (the offline image
// has no `xla` crate; the default build substitutes a stub whose `load`
// always errors). Without the feature these tests cannot even bind
// artifacts, so the whole file is compiled out.
#![cfg(feature = "xla")]

use pdgibbs::duality::DualModel;
use pdgibbs::graph::{FactorGraph, PairFactor};
use pdgibbs::inference::exact;
use pdgibbs::rng::{Pcg64, RngCore};
use pdgibbs::runtime::Runtime;
use pdgibbs::workloads;

fn runtime() -> Runtime {
    Runtime::load("artifacts").expect("run `make artifacts` before `cargo test`")
}

#[test]
fn manifest_lists_all_configs() {
    let rt = runtime();
    for name in ["grid16", "grid50", "fc100", "rand1000_k2"] {
        assert!(rt.manifest().get(name).is_some(), "missing artifact {name}");
    }
}

#[test]
fn grid16_compiles_and_runs() {
    let rt = runtime();
    let meta = rt.manifest().get("grid16").unwrap().clone();
    let g = workloads::ising_grid(16, 16, 0.25, 0.0);
    let m = DualModel::from_graph(&g);
    let ops = m.dense_operands(meta.n_pad, meta.f_pad);
    let exec = rt.chain_exec("grid16", &ops).expect("bind");
    let out = exec.run(&exec.zero_state(), [7, 9]).expect("run");
    // shapes
    assert_eq!(out.state.x.len(), meta.chains * meta.n_pad);
    assert_eq!(out.sum_x.len(), meta.chains * meta.n_pad);
    assert_eq!(out.mag.len(), meta.sweeps * meta.chains);
    // x is binary, sums bounded by sweep count
    assert!(out.state.x.iter().all(|&v| v == 0.0 || v == 1.0));
    assert!(out.sum_x.iter().all(|&s| (0.0..=meta.sweeps as f32).contains(&s)));
    // magnetization of a zero-field Ising grid stays in (0, 1) and moves
    let m0 = out.mag[0];
    let m_last = out.mag[out.mag.len() - 1];
    assert!(m0 > 0.0 && m0 < 1.0, "mag {m0}");
    assert!(m_last > 0.0 && m_last < 1.0);
}

#[test]
fn chunked_execution_continues_the_chain() {
    let rt = runtime();
    let meta = rt.manifest().get("grid16").unwrap().clone();
    let g = workloads::ising_grid(16, 16, 0.3, 0.1);
    let m = DualModel::from_graph(&g);
    let ops = m.dense_operands(meta.n_pad, meta.f_pad);
    let exec = rt.chain_exec("grid16", &ops).unwrap();
    // same key, same start => identical outputs (deterministic replay)
    let a = exec.run(&exec.zero_state(), [1, 2]).unwrap();
    let b = exec.run(&exec.zero_state(), [1, 2]).unwrap();
    assert_eq!(a.state.x, b.state.x);
    assert_eq!(a.mag, b.mag);
    // different key => different trajectory
    let c = exec.run(&exec.zero_state(), [3, 4]).unwrap();
    assert_ne!(a.state.x, c.state.x);
    // chaining: second chunk starts from first chunk's state
    let d = exec.run(&a.state, [5, 6]).unwrap();
    assert_ne!(d.state.x, a.state.x);
}

#[test]
fn padding_stays_inert_across_chunks() {
    let rt = runtime();
    let meta = rt.manifest().get("grid16").unwrap().clone();
    // a graph smaller than the artifact: 10x10 grid in a 256-var artifact
    let g = workloads::ising_grid(10, 10, 0.3, 0.2);
    let m = DualModel::from_graph(&g);
    let ops = m.dense_operands(meta.n_pad, meta.f_pad);
    let exec = rt.chain_exec("grid16", &ops).unwrap();
    let mut state = exec.zero_state();
    let mut rng = Pcg64::seed(5);
    for _ in 0..4 {
        let out = exec.run(&state, [rng.next_u64() as u32, rng.next_u64() as u32]).unwrap();
        state = out.state;
        for c in 0..meta.chains {
            let row = &state.x[c * meta.n_pad..(c + 1) * meta.n_pad];
            assert!(
                row[100..].iter().all(|&v| v == 0.0),
                "padded variables flipped on"
            );
        }
    }
}

#[test]
fn xla_chain_matches_exact_marginals() {
    // THE cross-stack test: python-lowered chain == rust exact enumeration.
    // Small model (3x3 grid) embedded in the grid16 artifact.
    let rt = runtime();
    let meta = rt.manifest().get("grid16").unwrap().clone();
    let mut g = workloads::ising_grid(3, 3, 0.4, 0.15);
    // add an anti-ferromagnetic edge to exercise the Lemma-4 swap path
    g.add_factor(PairFactor::ising(0, 8, -0.3));
    let m = DualModel::from_graph(&g);
    let ops = m.dense_operands(meta.n_pad, meta.f_pad);
    let exec = rt.chain_exec("grid16", &ops).unwrap();

    let mut state = exec.zero_state();
    let mut rng = Pcg64::seed(11);
    let mut sum = vec![0.0f64; 9];
    let burn_chunks = 12; // 12 * 8 = 96 burn-in sweeps
    let keep_chunks = 1500; // 1500 * 8 * 4 chains = 48k samples
    for chunk in 0..burn_chunks + keep_chunks {
        let out = exec
            .run(&state, [rng.next_u64() as u32, rng.next_u64() as u32])
            .unwrap();
        state = out.state;
        if chunk >= burn_chunks {
            for c in 0..meta.chains {
                for v in 0..9 {
                    sum[v] += out.sum_x[c * meta.n_pad + v] as f64;
                }
            }
        }
    }
    let total = (keep_chunks * meta.sweeps * meta.chains) as f64;
    let want = exact::enumerate(&g).marginals;
    for v in 0..9 {
        let got = sum[v] / total;
        assert!(
            (got - want[v]).abs() < 0.015,
            "v={v}: xla {got:.4} vs exact {:.4}",
            want[v]
        );
    }
}

#[test]
fn operand_padding_mismatch_is_rejected() {
    let rt = runtime();
    let g = FactorGraph::new(4);
    let m = DualModel::from_graph(&g);
    let ops = m.dense_operands(8, 8); // wrong padding for grid16
    assert!(rt.chain_exec("grid16", &ops).is_err());
}

#[test]
fn unknown_artifact_is_rejected() {
    let rt = runtime();
    let g = FactorGraph::new(4);
    let m = DualModel::from_graph(&g);
    let ops = m.dense_operands(256, 512);
    assert!(rt.chain_exec("nope", &ops).is_err());
}
