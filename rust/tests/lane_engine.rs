//! Integration: the lane-batched engine vs the scalar sampler and exact
//! oracles — determinism contracts and marginal agreement at the same
//! tolerances as `sampler_agreement.rs`.
//!
//! Determinism contracts under test:
//!
//! * `PdSampler::sweep_parallel`: same seed + same pool SIZE ⇒
//!   bit-identical `state()` traces (chunk streams depend on the chunk
//!   count only); different pool sizes change the streams but must leave
//!   the stationary distribution intact.
//! * `LanePdSampler`: stronger — streams are keyed `(sweep, site)`, so the
//!   trajectory is bit-identical for EVERY pool size, including none.

use std::sync::Arc;

use pdgibbs::engine::LanePdSampler;
use pdgibbs::graph::{FactorGraph, PairFactor};
use pdgibbs::inference::exact;
use pdgibbs::rng::Pcg64;
use pdgibbs::samplers::{empirical_marginals, PdSampler, Sampler};
use pdgibbs::util::ThreadPool;
use pdgibbs::workloads;

fn lane_marginals(eng: &mut LanePdSampler, burn: usize, sweeps: usize) -> Vec<f64> {
    for _ in 0..burn {
        eng.sweep();
    }
    let n = eng.num_vars();
    let mut acc = vec![0.0f64; n];
    for _ in 0..sweeps {
        eng.sweep();
        for (v, a) in acc.iter_mut().enumerate() {
            *a += eng.popcount_var(v) as f64;
        }
    }
    let denom = (sweeps * eng.lanes()) as f64;
    acc.into_iter().map(|a| a / denom).collect()
}

#[test]
fn lane_engine_matches_exact_on_ferromagnetic_grid() {
    // same oracle + tolerance as sampler_agreement.rs
    let g = workloads::ising_grid(3, 3, 0.45, 0.2);
    let want = exact::enumerate(&g).marginals;
    let mut eng = LanePdSampler::new(&g, 64, 31);
    let got = lane_marginals(&mut eng, 500, 2500);
    for v in 0..9 {
        assert!(
            (got[v] - want[v]).abs() < 0.015,
            "v={v}: {} vs exact {}",
            got[v],
            want[v]
        );
    }
}

#[test]
fn lane_engine_matches_exact_on_frustrated_model() {
    // the mixed-sign model from sampler_agreement.rs
    let mut g = FactorGraph::new(8);
    for v in 0..8 {
        g.set_unary(v, 0.3 * ((v % 3) as f64 - 1.0));
    }
    for &(a, b, beta) in &[
        (0usize, 1usize, 0.5f64),
        (1, 2, -0.4),
        (2, 3, 0.6),
        (3, 0, -0.5),
        (4, 5, 0.3),
        (5, 6, -0.6),
        (6, 7, 0.4),
        (7, 4, 0.2),
        (0, 4, -0.3),
        (2, 6, 0.35),
    ] {
        g.add_factor(PairFactor::ising(a, b, beta));
    }
    let want = exact::enumerate(&g).marginals;
    let mut eng = LanePdSampler::new(&g, 64, 32);
    let got = lane_marginals(&mut eng, 500, 3000);
    for v in 0..8 {
        assert!(
            (got[v] - want[v]).abs() < 0.015,
            "v={v}: {} vs exact {}",
            got[v],
            want[v]
        );
    }
}

#[test]
fn lane_engine_bit_identical_across_pool_sizes() {
    let g = workloads::ising_grid(4, 4, 0.3, 0.1);
    let mut serial = LanePdSampler::new(&g, 70, 9);
    let mut pooled2 = LanePdSampler::new(&g, 70, 9).with_pool(Arc::new(ThreadPool::new(2)));
    let mut pooled5 = LanePdSampler::new(&g, 70, 9).with_pool(Arc::new(ThreadPool::new(5)));
    for sweep in 0..40 {
        serial.sweep();
        pooled2.sweep();
        pooled5.sweep();
        assert_eq!(
            serial.state_words(),
            pooled2.state_words(),
            "x diverged at sweep {sweep} (pool 2)"
        );
        assert_eq!(
            serial.state_words(),
            pooled5.state_words(),
            "x diverged at sweep {sweep} (pool 5)"
        );
        assert_eq!(
            serial.theta_words(),
            pooled5.theta_words(),
            "theta diverged at sweep {sweep}"
        );
    }
}

#[test]
fn pd_sampler_bit_identical_for_same_pool_size() {
    let g = workloads::ising_grid(4, 4, 0.35, 0.05);
    let mut a = PdSampler::new(&g).with_pool(Arc::new(ThreadPool::new(2)));
    let mut b = PdSampler::new(&g).with_pool(Arc::new(ThreadPool::new(2)));
    let mut rng_a = Pcg64::seed(17);
    let mut rng_b = Pcg64::seed(17);
    for sweep in 0..60 {
        a.sweep(&mut rng_a);
        b.sweep(&mut rng_b);
        assert_eq!(a.state(), b.state(), "state diverged at sweep {sweep}");
        assert_eq!(a.theta(), b.theta(), "theta diverged at sweep {sweep}");
    }
}

#[test]
fn pd_sampler_pool_size_does_not_bias_marginals() {
    // different pool sizes select different chunk streams — trajectories
    // differ, but the sampled distribution must not
    let g = workloads::ising_grid(3, 3, 0.25, 0.05);
    let want = exact::enumerate(&g).marginals;
    for pool_size in [2usize, 4] {
        let mut s = PdSampler::new(&g).with_pool(Arc::new(ThreadPool::new(pool_size)));
        let mut rng = Pcg64::seed(23);
        let marg = empirical_marginals(&mut s, &mut rng, 500, 15_000);
        for v in 0..9 {
            assert!(
                (marg[v] - want[v]).abs() < 0.035,
                "pool {pool_size} v={v}: {} vs exact {}",
                marg[v],
                want[v]
            );
        }
    }
}

#[test]
fn lane_engine_churn_mid_run_matches_exact() {
    // add_factor/remove_factor apply once to the shared model for all lanes
    let mut g = workloads::ising_grid(2, 3, 0.3, 0.1);
    let mut eng = LanePdSampler::new(&g, 64, 12).with_pool(Arc::new(ThreadPool::new(2)));
    for _ in 0..100 {
        eng.sweep();
    }
    let added = g.add_factor(PairFactor::ising(0, 4, 0.5));
    eng.add_factor(added, g.factor(added).unwrap());
    let victim = g.factors().next().unwrap().0;
    g.remove_factor(victim).unwrap();
    eng.remove_factor(victim);
    let got = lane_marginals(&mut eng, 300, 2000);
    let want = exact::enumerate(&g).marginals;
    for v in 0..6 {
        assert!(
            (got[v] - want[v]).abs() < 0.015,
            "v={v}: {} vs exact {}",
            got[v],
            want[v]
        );
    }
}
