//! Quickstart: dualize a small MRF, sample it in parallel, compare against
//! exact marginals, and estimate the log-partition function.
//!
//!     cargo run --release --example quickstart
//!
//! This is the 60-second tour of the public API:
//!   1. build a [`pdgibbs::FactorGraph`] (here: a 4×4 Ising grid),
//!   2. the primal–dual sampler needs *no coloring and no preprocessing*
//!      beyond one 2×2 factorization per factor,
//!   3. sample; 4. validate against brute-force enumeration;
//!   5. bound log Z with the §5.2 estimator.

use pdgibbs::duality::DualModel;
use pdgibbs::inference::{exact, partition};
use pdgibbs::rng::Pcg64;
use pdgibbs::samplers::{empirical_marginals, PdSampler, Sampler};
use pdgibbs::workloads;

fn main() {
    // 1. the model: 4×4 ferromagnetic Ising grid with a weak field
    let g = workloads::ising_grid(4, 4, 0.3, 0.1);
    println!(
        "model: {} variables, {} factors (4x4 Ising grid, beta=0.3, h=0.1)",
        g.num_vars(),
        g.num_factors()
    );

    // 2. dualize + sample — the paper's parallel Gibbs sampler
    let mut sampler = PdSampler::new(&g);
    let mut rng = Pcg64::seed(42);
    println!("sampler: {} (no graph coloring required)", sampler.name());

    // 3. draw marginals
    let marg = empirical_marginals(&mut sampler, &mut rng, 1_000, 100_000);

    // 4. compare with exact enumeration (16 variables => 65536 states)
    let truth = exact::enumerate(&g);
    let max_err = marg
        .iter()
        .zip(&truth.marginals)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    println!("\n   var   sampled    exact");
    for v in [0, 5, 10, 15] {
        println!(
            "   x{v:<4} {:.4}    {:.4}",
            marg[v], truth.marginals[v]
        );
    }
    println!("max marginal error over all 16 variables: {max_err:.4}");
    assert!(max_err < 0.02, "sampler disagrees with exact enumeration");

    // 5. log-partition estimation (§5.2): E[log V] lower-bounds log Z
    let model = DualModel::from_graph(&g);
    let est = partition::estimate_log_z(&model, 1_000, 20_000, 7);
    let offset = partition::dualization_log_scale(&g, &model);
    let bound = est.lower_bound + offset;
    println!(
        "\nlog Z: exact {:.4}; paper's E[log V] lower bound {:.4} (± {:.4})",
        truth.log_z, bound, est.std_err
    );
    assert!(
        bound <= truth.log_z + 4.0 * est.std_err,
        "E[log V] bound violated"
    );
    assert!(bound > truth.log_z - 8.0, "bound uselessly loose");
    println!("\nquickstart OK");
}
