//! Parallel MAP and mean-field inference (§5.3) on a frustrated MRF.
//!
//!     cargo run --release --example map_inference
//!
//! Compares, on a random graph with mixed couplings:
//!   * ICM (sequential coordinate ascent) vs the paper's parallel PD-EM,
//!   * naive mean-field vs parallel PD mean-field vs the recommended
//!     PD-then-naive pipeline (Lemma 6: PD alone optimizes an upper bound,
//!     so fine-tuning should only help),
//! and validates everything against brute-force enumeration.

use pdgibbs::duality::DualModel;
use pdgibbs::inference::{em_map, exact, mean_field};
use pdgibbs::workloads;

fn main() {
    let g = workloads::random_graph(16, 2, 1.0, 7);
    let m = DualModel::from_graph(&g);
    let truth = exact::enumerate(&g);
    println!(
        "model: {} vars, {} factors (random graph, N(0,1) log-potentials)",
        g.num_vars(),
        g.num_factors()
    );
    println!("exact: log Z = {:.4}, MAP log p = {:.4}", truth.log_z, truth.map_log_prob);

    // -- MAP --------------------------------------------------------------
    println!("\nMAP inference:");
    let (x_icm, it_icm) = em_map::icm(&g, &vec![0u8; 16], 500);
    let (x_em, it_em) = em_map::pd_em(&m, &vec![0u8; 16], 500);
    let lp = |x: &[u8]| g.log_prob_unnorm(x);
    println!(
        "  ICM   (sequential): log p = {:.4} in {it_icm} iters  (gap to MAP {:.4})",
        lp(&x_icm),
        truth.map_log_prob - lp(&x_icm)
    );
    println!(
        "  PD-EM (parallel)  : log p = {:.4} in {it_em} iters  (gap to MAP {:.4})",
        lp(&x_em),
        truth.map_log_prob - lp(&x_em)
    );

    // restarts close the gap: EM is monotone from any init
    let mut best = lp(&x_em);
    for seed in 0..8u8 {
        let init: Vec<u8> = (0..16u8).map(|v| (v ^ seed) & 1).collect();
        let (x, _) = em_map::pd_em(&m, &init, 500);
        best = best.max(lp(&x));
    }
    println!("  PD-EM best of 9 restarts: log p = {best:.4}");

    // -- mean-field ---------------------------------------------------------
    println!("\nmean-field inference (free energy F; exact -log Z = {:.4}):", -truth.log_z);
    let naive = mean_field::naive(&g, 500, 1e-10);
    let (eta, _, pd_iters) = mean_field::primal_dual(&m, 500, 1e-10);
    let f_pd = mean_field::free_energy(&g, &eta);
    let pipeline = mean_field::pd_then_naive(&g, &m, 500, 500, 1e-10);
    println!("  naive MF        : F = {:.4} ({} iters)", naive.free_energy, naive.iters);
    println!("  PD-MF (parallel): F = {:.4} ({pd_iters} iters)", f_pd);
    println!(
        "  PD then naive   : F = {:.4} ({} iters total)",
        pipeline.free_energy, pipeline.iters
    );
    // Lemma 6 in practice: fine-tuning never hurts
    assert!(pipeline.free_energy <= f_pd + 1e-9);
    // free energies upper-bound -log Z
    for (name, f) in [("naive", naive.free_energy), ("pd", f_pd), ("pipeline", pipeline.free_energy)] {
        assert!(f >= -truth.log_z - 1e-9, "{name} free energy below -logZ");
    }

    let max_err = pipeline
        .mu
        .iter()
        .zip(&truth.marginals)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    println!("  pipeline marginals vs exact: max |err| = {max_err:.4}");
    println!("\nmap_inference OK");
}
