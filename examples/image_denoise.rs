//! End-to-end driver: binary image denoising through ALL THREE LAYERS.
//!
//!     make artifacts && cargo run --release --example image_denoise
//!
//! Pipeline: synthetic 50×50 image → flip noise → posterior Ising MRF →
//! Theorem-2 dualization → dense operands → **AOT-compiled JAX model whose
//! x-update is the Pallas kernel, executed from Rust via PJRT** → pooled
//! marginals → thresholding → pixel accuracy. A native-sampler run of the
//! same posterior cross-checks the XLA path (both must land on the same
//! marginals up to Monte-Carlo noise). Results are recorded in
//! EXPERIMENTS.md §E2E.

use pdgibbs::bench_support::denoise_e2e;

fn main() {
    let artifacts = std::env::args()
        .nth(1)
        .unwrap_or_else(|| "artifacts".to_string());

    println!("== XLA path (grid50 artifact: L1 Pallas kernel + L2 scan + L3 rust) ==");
    let xla = match denoise_e2e(&artifacts, 0.12, 0.35, 40, 0, false, true) {
        Ok(r) => r,
        Err(e) => {
            eprintln!(
                "XLA path unavailable ({e:#}).\nRun `make artifacts` first; falling back to native only."
            );
            let native = denoise_e2e(&artifacts, 0.12, 0.35, 40, 0, true, true).unwrap();
            report("native", &native);
            return;
        }
    };
    report("xla/grid50", &xla);

    println!("\n== native path (sparse CPU sampler, same posterior) ==");
    let native = denoise_e2e(&artifacts, 0.12, 0.35, 40, 0, true, false).unwrap();
    report("native", &native);

    // cross-check: both backends sample the same posterior
    let gap = (xla.denoised_accuracy - native.denoised_accuracy).abs();
    println!("\nbackend agreement: |Δaccuracy| = {gap:.4}");
    assert!(gap < 0.02, "XLA and native backends disagree");
    assert!(xla.denoised_accuracy > xla.noisy_accuracy + 0.03);
    println!("image_denoise OK");
}

fn report(name: &str, r: &pdgibbs::bench_support::DenoiseResult) {
    println!(
        "[{name}] accuracy {:.4} -> {:.4} | {} sweeps in {:.2}s ({:.1} sweeps/s)",
        r.noisy_accuracy,
        r.denoised_accuracy,
        r.sweeps,
        r.seconds,
        r.sweeps as f64 / r.seconds
    );
}
