//! End-to-end driver: binary image denoising through ALL THREE LAYERS,
//! plus K-state label segmentation through the native engine under every
//! sweep policy.
//!
//!     make artifacts && cargo run --release --example image_denoise
//!
//! Binary pipeline: synthetic 50×50 image → flip noise → posterior Ising
//! MRF → Theorem-2 dualization → dense operands → **AOT-compiled JAX
//! model whose x-update is the Pallas kernel, executed from Rust via
//! PJRT** → pooled marginals → thresholding → pixel accuracy. A
//! native-sampler run of the same posterior cross-checks the XLA path
//! (both must land on the same marginals up to Monte-Carlo noise).
//!
//! K-state pipeline: synthetic 4-label image → symmetric channel noise →
//! clamped segmentation MRF (observation sites held as evidence) →
//! native lane engine under Exact, Minibatch, AND Blocked sweeps →
//! posterior argmax → label accuracy. All three policies target the same
//! clamped conditional law, so their accuracies must agree.
//!
//! Results are recorded in EXPERIMENTS.md §E2E.

use pdgibbs::bench_support::denoise_e2e;
use pdgibbs::duality::{BlockPolicy, MinibatchPolicy};
use pdgibbs::engine::{EngineConfig, KernelKind, LanePdSampler, SweepPolicy};
use pdgibbs::workloads;

fn main() {
    let artifacts = std::env::args()
        .nth(1)
        .unwrap_or_else(|| "artifacts".to_string());

    println!("== XLA path (grid50 artifact: L1 Pallas kernel + L2 scan + L3 rust) ==");
    let xla = match denoise_e2e(&artifacts, 0.12, 0.35, 40, 0, false, true) {
        Ok(r) => r,
        Err(e) => {
            eprintln!(
                "XLA path unavailable ({e:#}).\nRun `make artifacts` first; falling back to native only."
            );
            let native = denoise_e2e(&artifacts, 0.12, 0.35, 40, 0, true, true).unwrap();
            report("native", &native);
            kstate_segmentation();
            return;
        }
    };
    report("xla/grid50", &xla);

    println!("\n== native path (sparse CPU sampler, same posterior) ==");
    let native = denoise_e2e(&artifacts, 0.12, 0.35, 40, 0, true, false).unwrap();
    report("native", &native);

    // cross-check: both backends sample the same posterior
    let gap = (xla.denoised_accuracy - native.denoised_accuracy).abs();
    println!("\nbackend agreement: |Δaccuracy| = {gap:.4}");
    assert!(gap < 0.02, "XLA and native backends disagree");
    assert!(xla.denoised_accuracy > xla.noisy_accuracy + 0.03);

    kstate_segmentation();
    println!("image_denoise OK");
}

fn report(name: &str, r: &pdgibbs::bench_support::DenoiseResult) {
    println!(
        "[{name}] accuracy {:.4} -> {:.4} | {} sweeps in {:.2}s ({:.1} sweeps/s)",
        r.noisy_accuracy,
        r.denoised_accuracy,
        r.sweeps,
        r.seconds,
        r.sweeps as f64 / r.seconds
    );
}

/// K-state segmentation: the same posterior-denoising task at k = 4,
/// sampled under every sweep policy the engine serves. Observations are
/// clamped evidence sites, so this also drives the cardinality ×
/// evidence × policy composition end to end.
fn kstate_segmentation() {
    let (rows, cols, k, rho, coupling) = (24usize, 24usize, 4usize, 0.2, 0.6);
    let clean = workloads::synthetic_labels(rows, cols, k);
    let noisy = workloads::noisy_labels(&clean, k, rho, 11);
    let (g, evidence) = workloads::segmentation_mrf(rows, cols, k, coupling, rho, &noisy);
    let noisy_acc = workloads::label_accuracy(&clean, &noisy);
    println!("\n== K-state segmentation (k = {k}, {rows}x{cols}, channel noise {rho}) ==");
    println!("{}", workloads::render_labels(&noisy, rows, cols));

    // interior pixels have degree 5 (4 grid edges + the channel), so a
    // threshold-4 minibatch policy actually subsamples them
    let policies: [(&str, SweepPolicy); 3] = [
        ("exact", SweepPolicy::Exact),
        (
            "minibatch",
            SweepPolicy::Minibatch(MinibatchPolicy {
                degree_threshold: 4,
                ..MinibatchPolicy::default()
            }),
        ),
        ("blocked", SweepPolicy::Blocked(BlockPolicy { cap: 6, epoch: 16 })),
    ];
    let n = rows * cols;
    let (burn, measure) = (150usize, 250usize);
    let mut accs = Vec::new();
    for (name, sweep) in policies {
        let mut eng = LanePdSampler::with_config(
            &g,
            EngineConfig { lanes: 128, seed: 0x5E6, kernel: KernelKind::default(), sweep },
        );
        for &(site, lbl) in &evidence {
            eng.clamp(site, lbl).unwrap();
        }
        for _ in 0..burn {
            eng.sweep();
        }
        let mut counts = vec![0u64; n * k];
        for _ in 0..measure {
            eng.sweep();
            for v in 0..n {
                for s in 0..k {
                    counts[v * k + s] += u64::from(eng.popcount_state(v, s as u8));
                }
            }
        }
        let map: Vec<u8> = (0..n)
            .map(|v| {
                (0..k)
                    .max_by_key(|&s| counts[v * k + s])
                    .unwrap() as u8
            })
            .collect();
        let acc = workloads::label_accuracy(&clean, &map);
        let extra = match eng.sweep_policy() {
            SweepPolicy::Minibatch(_) => {
                let planned = (0..n).filter(|&v| eng.model().mb_plan(v).is_some()).count();
                format!(" | {planned} pixel sites minibatched")
            }
            SweepPolicy::Blocked(_) => {
                let (blocks, vars, _) = eng.block_summary();
                format!(" | {blocks} blocks over {vars} sites")
            }
            _ => String::new(),
        };
        println!("[segmentation/{name}] accuracy {noisy_acc:.4} -> {acc:.4}{extra}");
        assert!(
            acc > noisy_acc + 0.02,
            "{name}: posterior argmax must beat the noisy observation"
        );
        accs.push(acc);
    }
    // same clamped conditional law, three trajectories: accuracies agree
    for w in accs.windows(2) {
        assert!(
            (w[0] - w[1]).abs() < 0.05,
            "policies disagree on the segmentation posterior: {accs:?}"
        );
    }
    println!("kstate segmentation OK");
}
