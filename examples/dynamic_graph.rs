//! Dynamic-topology serving: the deployment the paper motivates.
//!
//!     cargo run --release --example dynamic_graph
//!
//! A coordinator hosts an MRF while factors are added/removed continuously
//! (synthetic churn trace — see DESIGN.md §Substitutions). Two contrasts:
//!
//! 1. *maintenance cost*: the primal–dual path pays one 2×2 factorization
//!    per insertion; the chromatic baseline must repair its coloring (we
//!    count touched variables and repair time).
//! 2. *inference continuity*: the server keeps answering marginal queries
//!    mid-churn, and after the trace settles the estimates match exact
//!    enumeration on the final graph.

use std::time::Instant;

use pdgibbs::coordinator::{Server, ServerConfig};
use pdgibbs::graph::{coloring, FactorGraph};
use pdgibbs::inference::exact;
use pdgibbs::workloads::{ChurnOp, ChurnTrace};

fn main() {
    let vars = 18; // small enough for exact validation at the end
    let steps = 400;
    let trace = ChurnTrace::generate(vars, 30, steps, 0.5, 2026);
    println!(
        "churn trace: {} ops over {} variables (target ~30 live factors)",
        trace.ops.len(),
        vars
    );

    // -- 1. maintenance cost comparison --------------------------------
    // primal-dual: dualize each inserted factor (the entire preprocessing)
    let t0 = Instant::now();
    let mut g = FactorGraph::new(vars);
    let mut live = Vec::new();
    let mut model = pdgibbs::DualModel::from_graph(&g);
    for op in &trace.ops {
        match *op {
            ChurnOp::Add { v1, v2, beta } => {
                let f = pdgibbs::graph::PairFactor::ising(v1, v2, beta);
                let id = g.add_factor(f);
                model.insert_at(id, g.factor(id).unwrap());
                live.push(id);
            }
            ChurnOp::RemoveLive { index } => {
                let id = live.swap_remove(index);
                g.remove_factor(id);
                model.remove(id);
            }
        }
    }
    let pd_time = t0.elapsed();

    // chromatic baseline: greedy color once, repair after every op
    let t0 = Instant::now();
    let mut g2 = FactorGraph::new(vars);
    let mut live2 = Vec::new();
    let mut col = coloring::greedy(&g2);
    let mut touched_total = 0usize;
    for op in &trace.ops {
        ChurnTrace::apply(&mut g2, &mut live2, op);
        touched_total += coloring::repair(&g2, &mut col);
    }
    let chrom_time = t0.elapsed();
    assert!(col.is_proper(&g2), "repair left an improper coloring");

    println!("\nmaintenance cost over {} ops:", trace.ops.len());
    println!(
        "  primal-dual : {:>8.2?} total ({:.1} us/op) — no coloring at all",
        pd_time,
        pd_time.as_secs_f64() * 1e6 / trace.ops.len() as f64
    );
    println!(
        "  chromatic   : {:>8.2?} total ({:.1} us/op), {} vars recolored, {} colors",
        chrom_time,
        chrom_time.as_secs_f64() * 1e6 / trace.ops.len() as f64,
        touched_total,
        col.num_colors
    );

    // -- 2. serving with continuous inference ---------------------------
    println!("\nserving the same trace with live inference:");
    let mut server = Server::spawn(
        FactorGraph::new(vars),
        ServerConfig {
            chains: 10,
            background_sweeps: 32,
            ..Default::default()
        },
    );
    let h = server.handle();
    let t0 = Instant::now();
    for (i, op) in trace.ops.iter().enumerate() {
        h.apply(vec![op.clone()]);
        h.sweep(8);
        if (i + 1) % 100 == 0 {
            let stats = h.stats().expect("server alive");
            println!(
                "  after {:>3} ops: {} live factors, {} sweeps served",
                i + 1,
                stats.num_factors,
                stats.sweeps_done
            );
        }
    }
    // settle and query
    h.sweep(500);
    h.reset_stats();
    h.sweep(30_000);
    let got = h.marginals().expect("server alive");
    let serve_time = t0.elapsed();

    // validate against exact enumeration of the final graph
    let (final_graph, _) = trace.materialize();
    let want = exact::enumerate(&final_graph);
    let max_err = got
        .iter()
        .zip(&want.marginals)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    println!(
        "\nfinal-state marginals vs exact: max error {max_err:.4} ({} factors live)",
        final_graph.num_factors()
    );
    println!("served trace + queries in {serve_time:.2?}");
    println!("metrics: {}", server.metrics.snapshot().dump());
    assert!(max_err < 0.03, "server marginals diverged from exact");
    server.shutdown();
    println!("\ndynamic_graph OK");
}
