//! Figure 2b: fully connected Ising model — where PD *wins*.
//!
//! Paper setup: fully connected Ising, N = 100, β ∈ [0.01, 0.015], 10
//! chains. No useful coloring exists (χ = N), so the comparison is PD
//! *full sweeps* against sequential *single-site updates*: one PD sweep
//! costs N parallel updates but 1 time-step; one sequential sweep costs N
//! serial updates. The paper reports PD mixing in fewer "parallel steps"
//! than the sequential sampler's site updates — i.e. the ratio
//! `seq_site_updates / pd_sweeps` exceeds 1 (improved mixing per unit of
//! parallel time).
//!
//! The bench reports both normalizations plus the jittered-coupling
//! variant the paper mentions (varying β breaks the Flach poly-time case).

use pdgibbs::bench::{Record, Report};
use pdgibbs::bench_support::{mixing_run, pick_monitors};
use pdgibbs::workloads;

fn main() {
    let full = std::env::var("PDGIBBS_SCALE").as_deref() == Ok("full");
    let (n, max_sweeps, chains) = if full { (100, 8000, 10) } else { (100, 4000, 10) };
    let betas = [0.010, 0.011, 0.012, 0.013, 0.014, 0.015];
    let threshold = 1.01;

    let mut report = Report::new("fig2b");
    println!(
        "fully connected Ising N={n}, {chains} chains, PSRF < {threshold}, budget {max_sweeps}\n"
    );
    for &beta in &betas {
        // paper convention (see fig2a.rs): symmetric-table beta = paper/2
        let b = beta / 2.0;
        for (variant, g) in [
            ("uniform", workloads::fully_connected_ising(n, |_, _| b)),
            (
                "jittered",
                workloads::fully_connected_jittered(n, b, 0.2, 99),
            ),
        ] {
            let monitors = pick_monitors(n, 16);
            let mut mixes = Vec::new();
            for kind in ["sequential", "pd"] {
                let r = mixing_run(&g, kind, chains, max_sweeps, threshold, &monitors, 4242);
                let sweeps = r.mixing_time.map(|t| t as f64).unwrap_or(f64::NAN);
                mixes.push(sweeps);
                report.push(
                    Record::new(format!("{kind}/{variant}"))
                        .param("beta", beta)
                        .metric("mix_sweeps", sweeps)
                        .metric(
                            "site_updates",
                            if kind == "sequential" { sweeps * n as f64 } else { sweeps },
                        )
                        .metric("final_psrf", r.final_psrf),
                );
            }
            // the paper's normalization: sequential single-site updates
            // vs PD full sweeps (parallel steps)
            if mixes.iter().all(|s| s.is_finite()) {
                report.push(
                    Record::new(format!("ratio/{variant}"))
                        .param("beta", beta)
                        .metric("seq_updates_over_pd_sweeps", mixes[0] * n as f64 / mixes[1]),
                );
            }
        }
    }
    report.finish();
}
