//! Figure 2a: Ising-grid mixing times, sequential vs primal–dual.
//!
//! Paper setup: 50×50 Ising grid, couplings β ∈ [0.1, 0.5], 10 chains,
//! mixing time = first sweep index after which PSRF stays below 1.01.
//! Expected *shape*: both samplers slow down with β; sequential mixes
//! faster, with a PD/sequential ratio between ~2 and ~7.
//!
//! Scale: `PDGIBBS_SCALE=full` reproduces the paper's 50×50 grid;
//! the default `quick` profile runs 24×24 with a reduced sweep budget so
//! `cargo bench` completes in minutes (documented in EXPERIMENTS.md; the
//! qualitative shape is identical).

use pdgibbs::bench::{Record, Report};
use pdgibbs::bench_support::{mixing_run, pick_monitors};
use pdgibbs::workloads;

fn main() {
    let full = std::env::var("PDGIBBS_SCALE").as_deref() == Ok("full");
    let (side, max_sweeps, chains) = if full { (50, 6000, 10) } else { (24, 2500, 10) };
    // paper convention: factor table [[e^b, 1], [1, e^b]] — equal (up to a
    // constant) to PairFactor::ising(b/2). Paper's b = 0.5 is subcritical
    // (2D Ising critical coupling b_c = ln(1+sqrt 2) ~ 0.88 in this
    // convention); our symmetric table uses beta = b/2.
    let betas = [0.1, 0.2, 0.3, 0.4, 0.5];
    let threshold = 1.01;

    let mut report = Report::new(if full { "fig2a_full" } else { "fig2a" });
    println!(
        "{side}x{side} Ising grid, {chains} chains, PSRF < {threshold}, budget {max_sweeps} sweeps\n"
    );
    for &beta in &betas {
        let g = workloads::ising_grid(side, side, beta / 2.0, 0.0);
        let monitors = pick_monitors(g.num_vars(), 24);
        let mut row: Vec<(String, f64)> = Vec::new();
        for kind in ["sequential", "pd"] {
            let t0 = std::time::Instant::now();
            let r = mixing_run(&g, kind, chains, max_sweeps, threshold, &monitors, 20_260_710);
            let sweeps = r.mixing_time.map(|t| t as f64).unwrap_or(f64::NAN);
            row.push((kind.to_string(), sweeps));
            report.push(
                Record::new(format!("{kind}"))
                    .param("beta", beta)
                    .metric("mix_sweeps", sweeps)
                    .metric("final_psrf", r.final_psrf)
                    .metric("wall_s", t0.elapsed().as_secs_f64()),
            );
        }
        if row.iter().all(|(_, s)| s.is_finite()) {
            let ratio = row[1].1 / row[0].1;
            report.push(
                Record::new("ratio pd/seq")
                    .param("beta", beta)
                    .metric("ratio", ratio),
            );
        }
    }
    report.finish();
}
