//! Blocking ablation (§5.4, Fig 1): does tree-blocking improve PD mixing?
//!
//! On grids with increasing coupling we compare plain PD, tree-blocked PD
//! (spanning tree conditioned exactly via forward-filter backward-sample),
//! and sequential Gibbs. Expected shape: blocked-PD mixes in fewer sweeps
//! than plain PD (the paper: "blocking generally improves mixing"),
//! approaching — and at strong coupling beating — sequential Gibbs, since
//! one blocked sweep redraws *all* variables jointly given off-tree duals.

use pdgibbs::bench::{Record, Report};
use pdgibbs::bench_support::{mixing_run, pick_monitors};
use pdgibbs::workloads;

fn main() {
    let full = std::env::var("PDGIBBS_SCALE").as_deref() == Ok("full");
    let (side, max_sweeps, chains) = if full { (32, 6000, 10) } else { (16, 3000, 10) };
    let betas = [0.2, 0.35, 0.5, 0.65];
    let threshold = 1.01;

    let mut report = Report::new("blocking");
    println!("{side}x{side} Ising grid, blocking ablation, PSRF < {threshold}\n");
    for &beta in &betas {
        let g = workloads::ising_grid(side, side, beta, 0.0);
        let monitors = pick_monitors(g.num_vars(), 16);
        let mut mix = std::collections::BTreeMap::new();
        for kind in ["pd", "blocked", "sequential"] {
            let r = mixing_run(&g, kind, chains, max_sweeps, threshold, &monitors, 5_150);
            let sweeps = r.mixing_time.map(|t| t as f64).unwrap_or(f64::NAN);
            mix.insert(kind, sweeps);
            report.push(
                Record::new(kind)
                    .param("beta", beta)
                    .metric("mix_sweeps", sweeps)
                    .metric("final_psrf", r.final_psrf),
            );
        }
        if mix["pd"].is_finite() && mix["blocked"].is_finite() {
            report.push(
                Record::new("speedup blocked/pd")
                    .param("beta", beta)
                    .metric("pd_over_blocked", mix["pd"] / mix["blocked"]),
            );
        }
    }
    report.finish();
}
