//! Performance bench: sweep throughput per backend + thread scaling.
//!
//! Not a paper figure per se — this is deliverable (e): the hot-path
//! numbers behind EXPERIMENTS.md §Perf.
//!
//! `--mode full` (default) measures, on the Fig-2a grid50 and Fig-2b fc100
//! workloads:
//!
//!   * native PD sweeps/s at 1..T threads (site-updates/s),
//!   * sequential and chromatic baselines,
//!   * the XLA artifact path (L1 Pallas + L2 scan under PJRT), amortized
//!     per sweep, when `artifacts/` is built,
//!
//! `--mode lanes` measures the lane-batched multi-chain engine on a 64×64
//! Ising grid at 256 lanes — the batched-serving hot path (CSR arena,
//! cached conditional tables, degree-aware pooled chunking) — against
//! scalar `PdSampler` chains at the same per-chain work. The `--kernel`
//! flag selects which sweep-kernel implementation runs: `scalar` (per-lane
//! reference loops), `tiled` (SIMD-tiled 8-lane bodies + jump-ahead RNG,
//! the default engine kernel), `nightly-simd` (with that feature), or
//! `both` (default: scalar AND tiled, reporting the `tiled_vs_scalar`
//! ratio and asserting the two kernels' trajectories are bit-identical).
//! Acceptance (ISSUE 4): tiled ≥ 1.5× scalar-kernel sweeps/s on this
//! configuration with bit-identical marginals; the per-chain speedup vs
//! scalar `PdSampler` chains (ISSUE 1's ≥ 3×) is still reported.
//!
//! `--mode server` measures the sharded multi-tenant coordinator
//! (ISSUE 3): 64 tenants × 64 lanes spread over 4 shards, background
//! fair-share sweeping on, a paced foreground query load on top.
//! Reported: aggregate background sweeps/s across all tenants and the
//! request latency distribution (p50/p99).
//!
//! `--mode server-net` measures the same coordinator through the TCP
//! serving edge (ISSUE 6): a [`pdgibbs::coordinator::NetServer`] on an
//! ephemeral port, driven to saturation by the closed-loop
//! [`pdgibbs::workloads::run_net_load`] generator — tens of thousands
//! of simulated clients with bursty pipelined arrivals multiplexed over
//! a bounded socket pool. Reported: saturation request throughput, the
//! client-perceived round-trip latency distribution (p50/p99/p999,
//! queueing included), and the admission-control outcome mix
//! (`ok` / `overloaded` / error replies) under overload.
//!
//! `--mode minibatch` measures degree-sublinear minibatched sweeps on a
//! heavy-tailed power-law tenant (default 10⁶ variables, 8·10⁶ edges,
//! zipf(1.8) endpoints, degree-scaled couplings): the same engine sweeps
//! the same graph under the exact full-incidence policy and under
//! `SweepPolicy::Minibatch` (Poisson-thinned MIN-Gibbs site updates plus
//! strided θ refresh), and the tracked `speedup` metric is the ratio.
//! Acceptance (ISSUE 7): ≥ 5× vs the full-incidence path with the
//! minibatch lane paths passing the tier-3 exactness gates. Flags:
//! `--mb-vars`, `--mb-edges`, `--mb-threshold`, `--mb-stride`, `--k`
//! (variable cardinality, default 2 — k > 2 builds the same power-law
//! edge set over Potts tables and writes a `-k{k}`-suffixed record),
//! `--kernel` (single kernel, default tiled).
//!
//! `--mode blocked` measures adaptive tree-blocking on an above-critical
//! Ising grid with mid-run churn (default 16×16 at β = 0.5 > β_c): the
//! same engine, seed, kernel, and churn schedule run under the exact
//! flat policy and under `SweepPolicy::Blocked`, and the tracked
//! `speedup` metric is the ratio of **ESS/s** (effective samples of the
//! mean-magnetization trace per wall second) — mixing-per-second, the
//! only honest unit for a policy that deliberately spends more per
//! sweep. Acceptance (ISSUE 8): ≥ 1.5× ESS/s vs flat PD. Flags:
//! `--blk-rows`, `--blk-cols`, `--blk-beta`, `--blk-cap`, `--blk-epoch`,
//! `--blk-sweeps`, `--k` (variable cardinality, default 2 — k > 2 runs
//! a Potts grid just above its critical coupling and writes a
//! `-k{k}`-suffixed record), `--kernel` (single kernel, default tiled).
//!
//! `--mode validate` runs the statistical exactness gates (ISSUE 5) on a
//! fixed subset of the validation matrix — ground-truth forward draws,
//! scalar PD, lane engine under both stable kernels (incl. the dense
//! no-coloring K₁₀), and the live coordinator serving path — and records
//! the gate statistics (max marginal z, joint TV, chi-square, thresholds,
//! pass/fail). The full matrix lives in
//! `rust/tests/statistical_validation.rs`; this mode makes the gate
//! margins diffable PR over PR. Exits nonzero if any gate fails.
//!
//! All modes write the usual `target/bench-reports/throughput*.json` AND
//! a tracked file at the repository root so the perf trajectory is
//! diffable PR over PR: lanes mode owns `BENCH_throughput.json` (the
//! acceptance record), full mode writes `BENCH_throughput_full.json`,
//! server and server-net modes write `BENCH_server.json` (tagged with
//! their mode), validate mode writes `BENCH_validate.json`, minibatch
//! mode writes `BENCH_throughput_minibatch.json`, blocked mode writes
//! `BENCH_throughput_blocked.json`.

use std::sync::Arc;
use std::time::{Duration, Instant};

use pdgibbs::bench::{time_fn, Record, Report};
use pdgibbs::coordinator::{Coordinator, CoordinatorConfig, NetConfig, NetServer, TenantConfig};
use pdgibbs::diagnostics::effective_sample_size;
use pdgibbs::duality::{BlockPolicy, DualModel, MinibatchPolicy};
use pdgibbs::engine::{EngineConfig, KernelKind, LanePdSampler, SweepPolicy};
use pdgibbs::graph::PairFactor;
use pdgibbs::rng::{Pcg64, RngCore};
use pdgibbs::runtime::Runtime;
use pdgibbs::samplers::{ChromaticGibbs, PdSampler, Sampler, SequentialGibbs};
use pdgibbs::util::ThreadPool;
use pdgibbs::workloads;

fn main() {
    match parse_mode().as_str() {
        "full" => bench_full(),
        "lanes" => bench_lanes(),
        "server" => bench_server(),
        "server-net" => bench_server_net(),
        "minibatch" => bench_minibatch(),
        "blocked" => bench_blocked(),
        "validate" => bench_validate(),
        other => {
            eprintln!(
                "unknown mode '{other}' \
                 (usage: throughput [--mode \
                 full|lanes|server|server-net|minibatch|blocked|validate])"
            );
            std::process::exit(2);
        }
    }
}

/// Value of `--<name> <value>`; unknown arguments (e.g. cargo's own
/// flags) are ignored so both `cargo bench` and direct invocation work.
fn parse_arg(name: &str) -> Option<String> {
    let args: Vec<String> = std::env::args().collect();
    let flag = format!("--{name}");
    args.iter()
        .position(|a| *a == flag)
        .and_then(|i| args.get(i + 1).cloned())
}

/// `--mode <full|lanes|server|server-net|minibatch|blocked|validate>`,
/// default `full`.
fn parse_mode() -> String {
    parse_arg("mode").unwrap_or_else(|| "full".to_string())
}

/// `--kernel <scalar|tiled|nightly-simd|both>` (lanes mode), default
/// `both`.
fn parse_kernels() -> Vec<KernelKind> {
    let arg = parse_arg("kernel").unwrap_or_else(|| "both".to_string());
    if arg == "both" {
        return vec![KernelKind::Scalar, KernelKind::Tiled];
    }
    match KernelKind::parse(&arg) {
        Some(k) => vec![k],
        None => {
            eprintln!(
                "unknown kernel '{arg}' (usage: throughput --mode lanes \
                 [--kernel scalar|tiled|nightly-simd|both])"
            );
            std::process::exit(2);
        }
    }
}

fn mean(xs: &[f64]) -> f64 {
    xs.iter().sum::<f64>() / xs.len() as f64
}

// -- lanes mode -------------------------------------------------------------

const LANES: usize = 256;
const SCALAR_CHAINS: usize = 64;
const GRID: &str = "64x64";

/// Assert that every compiled-in kernel under test samples the exact same
/// trajectory (packed x and θ words equal after every sweep) — the bench
/// refuses to report a speedup bought with a different chain.
fn assert_kernels_bit_identical(
    g: &pdgibbs::graph::FactorGraph,
    kernels: &[KernelKind],
    sweeps: usize,
) {
    let mut engines: Vec<LanePdSampler> = kernels
        .iter()
        .map(|&k| LanePdSampler::new(g, LANES, 0xBEEF).with_kernel(k))
        .collect();
    for sweep in 0..sweeps {
        for eng in engines.iter_mut() {
            eng.sweep();
        }
        let (first, rest) = engines.split_first().unwrap();
        for eng in rest {
            assert_eq!(
                first.state_words(),
                eng.state_words(),
                "x diverged: {} vs {} at sweep {sweep}",
                first.kernel().name(),
                eng.kernel().name()
            );
            assert_eq!(
                first.theta_words(),
                eng.theta_words(),
                "theta diverged: {} vs {} at sweep {sweep}",
                first.kernel().name(),
                eng.kernel().name()
            );
        }
    }
}

fn bench_lanes() {
    let kernels = parse_kernels();
    let mut report = Report::new("throughput-lanes");
    let g = workloads::ising_grid(64, 64, 0.3, 0.0);
    let n = g.num_vars() as f64;
    let sweeps_per_rep = 5usize;

    // baseline: scalar chains swept back-to-back on one thread (the
    // pre-engine ensemble execution model). Scalar throughput is linear
    // in the chain count, so 64 chains suffice to fix the per-chain rate.
    let base = Pcg64::seed(0xBEEF);
    let mut chains: Vec<(PdSampler, Pcg64)> = (0..SCALAR_CHAINS)
        .map(|c| (PdSampler::new(&g), base.split(c as u64 + 1)))
        .collect();
    let times = time_fn(1, 8, || {
        for _ in 0..sweeps_per_rep {
            for (s, rng) in chains.iter_mut() {
                s.sweep(rng);
            }
        }
    });
    let scalar_s = mean(&times) / sweeps_per_rep as f64; // s per all-chain sweep
    let scalar_chain_rate = SCALAR_CHAINS as f64 / scalar_s;
    push_lane_metrics(&mut report, "pd-scalar", "none", SCALAR_CHAINS, n, scalar_s, 0);

    // the determinism contract before any timing: same trajectory from
    // every kernel under test
    if kernels.len() > 1 {
        assert_kernels_bit_identical(&g, &kernels, 50);
        println!(
            "kernels {:?} bit-identical over 50 sweeps at {} lanes",
            kernels.iter().map(|k| k.name()).collect::<Vec<_>>(),
            LANES
        );
    }

    // lane engine per kernel, single-threaded — the tracked PR-over-PR
    // numbers — then pooled (degree-aware cache-line-aligned chunks)
    let max_threads = ThreadPool::default_size();
    let mut thread_counts = vec![2usize, 4];
    if max_threads > 4 {
        thread_counts.push(max_threads);
    }
    // per kernel: (kernel, single-thread s/sweep, best s/sweep incl. pools)
    let mut kernel_runs: Vec<(KernelKind, f64, f64)> = Vec::new();
    for &kernel in &kernels {
        let mut eng = LanePdSampler::new(&g, LANES, 0xBEEF).with_kernel(kernel);
        let times = time_fn(1, 8, || {
            for _ in 0..sweeps_per_rep {
                eng.sweep();
            }
        });
        let lane_s = mean(&times) / sweeps_per_rep as f64;
        push_lane_metrics(&mut report, "pd-lanes", kernel.name(), LANES, n, lane_s, 0);
        let mut best_s = lane_s;

        for &t in &thread_counts {
            let mut eng = LanePdSampler::new(&g, LANES, 0xBEEF)
                .with_kernel(kernel)
                .with_pool(Arc::new(ThreadPool::new(t)));
            let times = time_fn(1, 8, || {
                for _ in 0..sweeps_per_rep {
                    eng.sweep();
                }
            });
            let s = mean(&times) / sweeps_per_rep as f64;
            best_s = best_s.min(s);
            push_lane_metrics(&mut report, "pd-lanes-pooled", kernel.name(), LANES, n, s, t);
        }
        kernel_runs.push((kernel, lane_s, best_s));
    }

    // headline engine number: the default (tiled) kernel if measured,
    // else whatever single kernel was requested — speedups below are
    // the HEADLINE kernel's own runs, never another kernel's
    let (headline_kernel, headline_s, headline_best) = kernel_runs
        .iter()
        .find(|(k, _, _)| *k == KernelKind::default())
        .copied()
        .unwrap_or(kernel_runs[0]);

    // per-chain-sweep throughput ratio (chain counts differ, rates don't)
    let speedup = (LANES as f64 / headline_s) / scalar_chain_rate;
    let speedup_pooled = (LANES as f64 / headline_best) / scalar_chain_rate;
    let mut cmp = Record::new("lanes-vs-scalar")
        .param("workload", "grid64")
        .param("grid", GRID)
        .param("kernel", headline_kernel.name())
        .metric("speedup_1t", speedup)
        .metric("speedup_best", speedup_pooled);
    // the ISSUE-4 acceptance ratio: tiled vs scalar KERNEL, single thread
    let find = |k: KernelKind| {
        kernel_runs
            .iter()
            .find(|(kk, _, _)| *kk == k)
            .map(|&(_, s, _)| s)
    };
    if let (Some(sc), Some(ti)) = (find(KernelKind::Scalar), find(KernelKind::Tiled)) {
        let ratio = sc / ti;
        cmp = cmp.metric("tiled_vs_scalar_1t", ratio);
        println!(
            "tiled kernel vs scalar kernel (1 thread): {ratio:.2}x \
             (target >= 1.5x, bit-identical trajectories)"
        );
        if ratio < 1.5 {
            println!("WARNING: tiled kernel below the 1.5x acceptance target");
        }
    }
    report.push(cmp);
    println!(
        "lane engine ({}) per-chain speedup vs scalar chains: {speedup:.2}x single-thread, \
         {speedup_pooled:.2}x best-pooled (target >= 3x); \
         engine sweeps/s 1t: {:.2}",
        headline_kernel.name(),
        1.0 / headline_s
    );
    if speedup < 3.0 {
        println!("WARNING: single-thread lane speedup below the 3x acceptance target");
    }
    report.finish_tracked("throughput", "lanes");
}

#[allow(clippy::too_many_arguments)]
fn push_lane_metrics(
    report: &mut Report,
    label: &str,
    kernel: &str,
    lanes: usize,
    n: f64,
    per_sweep_s: f64,
    threads: usize,
) {
    report.push(
        Record::new(label)
            .param("workload", "grid64")
            .param("grid", GRID)
            .param("kernel", kernel)
            .param("lanes", lanes)
            .param("threads", threads)
            .metric("sweep_ms", per_sweep_s * 1e3)
            .metric("sweeps_per_s", 1.0 / per_sweep_s)
            .metric("chain_sweeps_per_s", lanes as f64 / per_sweep_s)
            .metric("Msite_updates_per_s", lanes as f64 * n / per_sweep_s / 1e6),
    );
}

// -- server mode ------------------------------------------------------------

const SERVER_TENANTS: u64 = 64;
const SERVER_LANES: usize = 64;
const SERVER_SHARDS: usize = 4;

/// Sorted-sample percentile (nearest-rank).
fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = ((sorted.len() as f64 * p).ceil() as usize).clamp(1, sorted.len());
    sorted[rank - 1]
}

fn bench_server() {
    let mut report = Report::new("throughput-server");
    let mut coord = Coordinator::spawn(CoordinatorConfig {
        shards: SERVER_SHARDS,
        pool_threads: 0,
        quantum: 16 * 1024,
        ..Default::default()
    });
    let client = coord.client();
    // 64 tenants × 64 lanes, each an 8×8 Ising grid (64 vars, 112 factors)
    for t in 0..SERVER_TENANTS {
        client
            .create_tenant(
                t,
                workloads::ising_grid(8, 8, 0.3, 0.0),
                TenantConfig {
                    chains: SERVER_LANES,
                    seed: 0xBEEF ^ t,
                    ..TenantConfig::default()
                },
            )
            .expect("create tenant");
    }
    // warm up the background scheduler before measuring
    std::thread::sleep(Duration::from_millis(200));
    let sweeps_at = |client: &pdgibbs::coordinator::Client| -> u64 {
        (0..SERVER_TENANTS)
            .map(|t| client.stats(t).expect("stats").sweeps_done as u64)
            .sum()
    };
    let before = sweeps_at(&client);
    let t0 = Instant::now();
    // paced foreground query load: one marginals query per millisecond,
    // round-robin over tenants, while the background sweeper runs hot
    let mut latencies = Vec::new();
    let mut i = 0u64;
    while t0.elapsed() < Duration::from_secs(2) {
        let tenant = i % SERVER_TENANTS;
        let q0 = Instant::now();
        let m = client.marginals(tenant).expect("marginals");
        latencies.push(q0.elapsed().as_secs_f64());
        assert_eq!(m.len(), 64);
        i += 1;
        std::thread::sleep(Duration::from_millis(1));
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let sweeps = sweeps_at(&client) - before;
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p50 = percentile(&latencies, 0.50);
    let p99 = percentile(&latencies, 0.99);
    let agg_sweeps_per_s = sweeps as f64 / elapsed;
    report.push(
        Record::new("coordinator-serving")
            .param("tenants", SERVER_TENANTS)
            .param("lanes", SERVER_LANES)
            .param("shards", SERVER_SHARDS)
            .param("grid", "8x8")
            .metric("agg_sweeps_per_s", agg_sweeps_per_s)
            .metric(
                "agg_chain_sweeps_per_s",
                agg_sweeps_per_s * SERVER_LANES as f64,
            )
            .metric("requests", latencies.len() as f64)
            .metric("request_p50_ms", p50 * 1e3)
            .metric("request_p99_ms", p99 * 1e3),
    );
    println!(
        "server mode: {} tenants x {} lanes on {} shards — {agg_sweeps_per_s:.0} aggregate \
         sweeps/s, request p50 {:.3} ms / p99 {:.3} ms over {} requests",
        SERVER_TENANTS,
        SERVER_LANES,
        SERVER_SHARDS,
        p50 * 1e3,
        p99 * 1e3,
        latencies.len()
    );
    coord.shutdown();
    report.finish_tracked("server", "server");
}

// -- server-net mode --------------------------------------------------------

fn bench_server_net() {
    let mut report = Report::new("throughput-server-net");
    let mut coord = Coordinator::spawn(CoordinatorConfig {
        shards: SERVER_SHARDS,
        pool_threads: 0,
        quantum: 8192,
        ..Default::default()
    });
    let net_config = NetConfig::default();
    let mut server = NetServer::spawn(
        coord.client(),
        coord.metrics().clone(),
        net_config.clone(),
        "127.0.0.1:0",
    )
    .expect("bind the serving edge on an ephemeral port");
    let load = workloads::NetLoadConfig {
        addr: server.addr().to_string(),
        ..Default::default()
    };
    println!(
        "server-net mode: {} logical clients x {} requests over {} sockets \
         against {} ({} tenants on {} shards)",
        load.logical_clients,
        load.requests_per_client,
        load.connections,
        server.addr(),
        load.tenants,
        SERVER_SHARDS
    );
    let r = workloads::run_net_load(&load).expect("net load generator");
    let coalesced = coord.metrics().counter("net.coalesced");
    let edge_requests = coord.metrics().counter("net.requests");
    server.shutdown();
    coord.shutdown();
    assert_eq!(
        r.parse_errors, 0,
        "a well-formed generator must never draw a parse error"
    );
    assert_eq!(r.sent, r.ok + r.overloaded + r.exec_errors, "closed loop must balance");
    let mut lat = r.latencies_s;
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let (p50, p99, p999) = (
        percentile(&lat, 0.50),
        percentile(&lat, 0.99),
        percentile(&lat, 0.999),
    );
    let rps = r.sent as f64 / r.elapsed_s;
    report.push(
        Record::new("serving-edge")
            .param("logical_clients", load.logical_clients)
            .param("connections", load.connections)
            .param("tenants", load.tenants)
            .param("shards", SERVER_SHARDS)
            .param("max_tenant_depth", net_config.max_tenant_depth)
            .param("max_shard_depth", net_config.max_shard_depth)
            .metric("requests", r.sent as f64)
            .metric("requests_per_s", rps)
            .metric("ok", r.ok as f64)
            .metric("overloaded", r.overloaded as f64)
            .metric("exec_errors", r.exec_errors as f64)
            .metric("coalesced", coalesced as f64)
            .metric("edge_requests", edge_requests as f64)
            .metric("rtt_p50_ms", p50 * 1e3)
            .metric("rtt_p99_ms", p99 * 1e3)
            .metric("rtt_p999_ms", p999 * 1e3)
            .metric("elapsed_s", r.elapsed_s),
    );
    println!(
        "server-net: {rps:.0} req/s saturation ({} sent, {} ok, {} overloaded, {} exec errors, \
         {coalesced} coalesced) — rtt p50 {:.3} ms / p99 {:.3} ms / p999 {:.3} ms",
        r.sent,
        r.ok,
        r.overloaded,
        r.exec_errors,
        p50 * 1e3,
        p99 * 1e3,
        p999 * 1e3
    );
    report.finish_tracked("server", "server-net");
}

// -- minibatch mode ---------------------------------------------------------

/// `--<name> <usize>` with a default.
fn parse_usize(name: &str, default: usize) -> usize {
    parse_arg(name).map_or(default, |v| {
        v.parse().unwrap_or_else(|_| panic!("--{name} wants an unsigned integer, got '{v}'"))
    })
}

/// `--mode minibatch`: one heavy-tailed power-law tenant, exact
/// full-incidence sweeps vs `SweepPolicy::Minibatch` on the same graph,
/// same kernel, same lane count. The tracked `speedup` metric is the
/// acceptance number (target ≥ 5×); both absolute sweep rates ride along
/// so "interactive rates at 10⁶ variables" stays a diffable claim rather
/// than a ratio that could be met by slowing the baseline. `--k` (default
/// 2) selects the variable cardinality: `k > 2` sweeps the Potts sibling
/// of the same power-law edge set through the per-state thinned path and
/// writes a `-k{k}`-suffixed record so cardinalities stay diffable side
/// by side.
fn bench_minibatch() {
    let vars = parse_usize("mb-vars", 1_000_000);
    let edges = parse_usize("mb-edges", 8 * vars);
    let threshold = parse_usize("mb-threshold", MinibatchPolicy::default().degree_threshold);
    let stride = parse_usize("mb-stride", 16);
    let k = parse_usize("k", 2);
    assert!((2..=8).contains(&k), "--k wants 2..=8, got {k}");
    let kernel = match parse_arg("kernel") {
        None => KernelKind::default(),
        Some(a) => KernelKind::parse(&a).unwrap_or_else(|| {
            eprintln!("unknown kernel '{a}' (--kernel scalar|tiled|nightly-simd)");
            std::process::exit(2);
        }),
    };
    let lanes = 64usize;
    let policy = MinibatchPolicy {
        degree_threshold: threshold,
        theta_stride: stride,
        ..MinibatchPolicy::default()
    };

    let mut report = Report::new("throughput-minibatch");
    println!(
        "minibatch mode: building power-law graph ({vars} vars, {edges} edges, k={k}, \
         zipf(1.8) endpoints, degree-scaled couplings)..."
    );
    let t0 = Instant::now();
    let g = workloads::power_law_graph_k(vars, edges, 1.8, 0.8, k, 0xBEEF);
    let build_s = t0.elapsed().as_secs_f64();
    let hub_degree = g.degree(0);
    println!("graph built in {build_s:.1}s, hub degree {hub_degree}");

    let sweep_once = |eng: &mut LanePdSampler| {
        let times = time_fn(1, 3, || eng.sweep());
        mean(&times)
    };

    let mut exact = LanePdSampler::with_config(
        &g,
        EngineConfig { lanes, seed: 0xBEEF, kernel, ..EngineConfig::default() },
    );
    let exact_cost = exact.cost();
    let exact_s = sweep_once(&mut exact);
    drop(exact);

    let mut mb = LanePdSampler::with_config(
        &g,
        EngineConfig { lanes, seed: 0xBEEF, kernel, sweep: SweepPolicy::Minibatch(policy) },
    );
    let planned = (0..vars).filter(|&v| mb.model().mb_plan(v).is_some()).count();
    let mb_cost = mb.cost();
    let mb_s = sweep_once(&mut mb);

    let speedup = exact_s / mb_s;
    // k > 2 gets its own record name so the binary acceptance row's
    // PR-over-PR diff is never polluted by a cardinality sweep
    let record = if k == 2 {
        "minibatch-vs-exact".to_string()
    } else {
        format!("minibatch-vs-exact-k{k}")
    };
    report.push(
        Record::new(record)
            .param("workload", "power-law")
            .param("k", k)
            .param("vars", vars)
            .param("edges", edges)
            .param("hub_degree", hub_degree)
            .param("planned_sites", planned)
            .param("kernel", kernel.name())
            .param("lanes", lanes)
            .param("degree_threshold", threshold)
            .param("theta_stride", stride)
            .metric("exact_sweep_s", exact_s)
            .metric("minibatch_sweep_s", mb_s)
            .metric("exact_sweeps_per_s", 1.0 / exact_s)
            .metric("minibatch_sweeps_per_s", 1.0 / mb_s)
            .metric(
                "minibatch_chain_sweeps_per_s",
                lanes as f64 / mb_s,
            )
            .metric("speedup", speedup)
            .metric("cost_ratio", exact_cost as f64 / mb_cost as f64)
            .metric("graph_build_s", build_s),
    );
    println!(
        "minibatch ({}) on {vars} vars / {edges} edges: exact {exact_s:.3} s/sweep, \
         minibatch {mb_s:.3} s/sweep ({:.1} sweeps/s) -> {speedup:.2}x \
         (target >= 5x; {planned} sites planned, scheduler cost ratio {:.2})",
        kernel.name(),
        1.0 / mb_s,
        exact_cost as f64 / mb_cost as f64
    );
    if speedup < 5.0 {
        println!("WARNING: minibatch speedup below the 5x acceptance target");
    }
    report.finish_tracked("throughput_minibatch", "minibatch");
}

// -- blocked mode ------------------------------------------------------------

/// `--<name> <f64>` with a default.
fn parse_f64(name: &str, default: f64) -> f64 {
    parse_arg(name).map_or(default, |v| {
        v.parse().unwrap_or_else(|_| panic!("--{name} wants a float, got '{v}'"))
    })
}

/// `--mode blocked`: an above-critical grid with mid-run churn, flat
/// exact PD sweeps vs `SweepPolicy::Blocked` on the same graph, kernel,
/// seed, and lane count. The tracked `speedup` metric is **ESS/s** —
/// mixing per wall second, not sweeps per second: blocked sweeps are
/// *slower* per sweep (joint tree draws cost more than flat site
/// visits) and win only if each sweep buys disproportionately more
/// effective samples. Target ≥ 1.5× on the default 16×16 β=0.5 grid.
/// Both runs cross the same churn ops at the same sweep indices, so the
/// adaptive re-planning path (not just a frozen plan) is on the clock.
///
/// `--k` (default 2) swaps the Ising grid for a K-state Potts grid with
/// Potts churn factors; the default β then scales to 1.1·ln(1+√k) —
/// just above the Potts critical coupling, where blocking pays. Records
/// for k > 2 get a `-k{k}` name suffix so the binary acceptance row's
/// PR-over-PR diff stays clean.
fn bench_blocked() {
    let rows = parse_usize("blk-rows", 16);
    let cols = parse_usize("blk-cols", 16);
    let k = parse_usize("k", 2);
    assert!((2..=8).contains(&k), "--k wants 2..=8, got {k}");
    let default_beta = if k == 2 { 0.5 } else { 1.1 * (1.0 + (k as f64).sqrt()).ln() };
    let beta = parse_f64("blk-beta", default_beta);
    let cap = parse_usize("blk-cap", BlockPolicy::default().cap);
    let epoch = parse_usize("blk-epoch", BlockPolicy::default().epoch);
    let sweeps = parse_usize("blk-sweeps", 4096);
    let kernel = match parse_arg("kernel") {
        None => KernelKind::default(),
        Some(a) => KernelKind::parse(&a).unwrap_or_else(|| {
            eprintln!("unknown kernel '{a}' (--kernel scalar|tiled|nightly-simd)");
            std::process::exit(2);
        }),
    };
    let lanes = 64usize;
    let mut report = Report::new("throughput-blocked");
    let critical = if k == 2 { 0.4407 } else { (1.0 + (k as f64).sqrt()).ln() };
    println!(
        "blocked mode: {rows}x{cols} k={k} grid at beta={beta:.4} (critical {critical:.4}), \
         {sweeps} timed sweeps x {lanes} lanes, churn at 1/2 and 3/4..."
    );

    // one timed run: warmup, then `sweeps` sweeps tracing mean lane
    // magnetization (k = 2) or the state-0 occupation fraction (k > 2;
    // ESS is invariant under that affine relabeling), with lockstep
    // churn ops at fixed sweep indices; returns (ess, wall seconds,
    // plan summary)
    let run = |sweep: SweepPolicy| -> (f64, f64, (usize, usize, usize)) {
        let mut g = if k == 2 {
            workloads::ising_grid(rows, cols, beta, 0.05)
        } else {
            workloads::potts_grid(rows, cols, k, beta)
        };
        let n = g.num_vars();
        let mut eng = LanePdSampler::with_config(
            &g,
            EngineConfig { lanes, seed: 0xB10C, kernel, sweep },
        );
        for _ in 0..256 {
            eng.sweep(); // burn-in (also grows the first block plans)
        }
        let denom = (n * lanes) as f64;
        let mut trace = Vec::with_capacity(sweeps);
        let mut added: Vec<usize> = Vec::new();
        let t0 = Instant::now();
        for s in 0..sweeps {
            if s == sweeps / 2 {
                // couple opposite corners: long-range edges blocks can't
                // absorb, forcing a re-plan under load
                for (a, b) in [(0usize, n - 1), (cols - 1, n - cols)] {
                    let f = if k == 2 {
                        PairFactor::ising(a, b, beta)
                    } else {
                        PairFactor::potts(a, b, beta)
                    };
                    let id = g.add_factor(f);
                    eng.add_factor(id, g.factor(id).unwrap());
                    added.push(id);
                }
            }
            if s == (3 * sweeps) / 4 {
                for id in added.drain(..) {
                    g.remove_factor(id).unwrap();
                    eng.remove_factor(id);
                }
            }
            eng.sweep();
            let ones: u64 = if k == 2 {
                eng.state_words().iter().map(|w| w.count_ones() as u64).sum()
            } else {
                (0..n).map(|v| u64::from(eng.popcount_state(v, 0))).sum()
            };
            trace.push(ones as f64 / denom);
        }
        let elapsed = t0.elapsed().as_secs_f64();
        (effective_sample_size(&trace), elapsed, eng.block_summary())
    };

    let (flat_ess, flat_s, _) = run(SweepPolicy::Exact);
    let (blk_ess, blk_s, (blocks, blocked_vars, tree_slots)) =
        run(SweepPolicy::Blocked(BlockPolicy { cap, epoch }));
    let flat_rate = flat_ess / flat_s;
    let blk_rate = blk_ess / blk_s;
    let speedup = blk_rate / flat_rate;
    let record = if k == 2 {
        "blocked-vs-flat-pd".to_string()
    } else {
        format!("blocked-vs-flat-pd-k{k}")
    };
    let workload = if k == 2 { "ising-grid-churn" } else { "potts-grid-churn" };
    report.push(
        Record::new(record)
            .param("workload", workload)
            .param("rows", rows)
            .param("cols", cols)
            .param("k", k)
            .param("beta", format!("{beta}"))
            .param("kernel", kernel.name())
            .param("lanes", lanes)
            .param("cap", cap)
            .param("epoch", epoch)
            .param("sweeps", sweeps)
            .param("blocks", blocks)
            .param("blocked_vars", blocked_vars)
            .param("tree_slots", tree_slots)
            .metric("flat_ess", flat_ess)
            .metric("blocked_ess", blk_ess)
            .metric("flat_wall_s", flat_s)
            .metric("blocked_wall_s", blk_s)
            .metric("flat_ess_per_s", flat_rate)
            .metric("blocked_ess_per_s", blk_rate)
            .metric("flat_sweeps_per_s", sweeps as f64 / flat_s)
            .metric("blocked_sweeps_per_s", sweeps as f64 / blk_s)
            .metric("speedup", speedup),
    );
    println!(
        "blocked ({}) on {rows}x{cols} k={k} beta={beta:.4}: flat {flat_rate:.1} ESS/s \
         ({:.0} sweeps/s), blocked {blk_rate:.1} ESS/s ({:.0} sweeps/s) \
         -> {speedup:.2}x ESS/s (target >= 1.5x; {blocks} blocks / \
         {blocked_vars} vars / {tree_slots} tree slots at finish)",
        kernel.name(),
        sweeps as f64 / flat_s,
        sweeps as f64 / blk_s,
    );
    if speedup < 1.5 {
        println!("WARNING: blocked ESS/s speedup below the 1.5x acceptance target");
    }
    report.finish_tracked("throughput_blocked", "blocked");
}

// -- validate mode ----------------------------------------------------------

/// Statistical exactness gates as a tracked bench artifact: a fixed
/// subset of the `tests/statistical_validation.rs` matrix (one row per
/// path × scenario), so the gate statistics themselves are diffable PR
/// over PR in `BENCH_validate.json`. The full matrix runs in the test
/// suite; this mode is the serving-stack sanity snapshot.
fn bench_validate() {
    use pdgibbs::validation::{
        validate, ClassicalPath, CoordinatorPath, ExactForward, GateConfig, LanePath,
        ValidationReport,
    };
    use pdgibbs::workloads::scenarios;

    let mut report = Report::new("validate");
    let mut all_passed = true;
    let push = |report: &mut Report, r: &ValidationReport, elapsed_s: f64| {
        println!("{}", r.summary());
        let mut rec = Record::new("validate")
            .param("path", r.path.clone())
            .param("scenario", r.scenario.clone())
            .metric("samples", r.samples as f64)
            .metric("max_z", r.max_z.stat)
            .metric("z_threshold", r.max_z.threshold)
            .metric("passed", if r.passed() { 1.0 } else { 0.0 })
            .metric("elapsed_s", elapsed_s);
        if let Some(tv) = &r.tv {
            rec = rec.metric("tv", tv.stat).metric("tv_threshold", tv.threshold);
        }
        if let Some((chi2, df)) = &r.chi2 {
            rec = rec
                .metric("chi2", chi2.stat)
                .metric("chi2_threshold", chi2.threshold)
                .metric("chi2_df", *df as f64);
        }
        report.push(rec);
    };

    // calibration row: iid ground-truth draws through the same gates
    {
        let s = scenarios::by_name("grid3x3-below");
        let mut fwd = ExactForward::new(&s.graph, 0xB001);
        let cfg = GateConfig { burn_in: 0, samples: 8192, tau: 1, ..GateConfig::default() };
        let t0 = Instant::now();
        let r = validate(&mut fwd, &s.graph, s.name, &cfg);
        all_passed &= r.passed();
        push(&mut report, &r, t0.elapsed().as_secs_f64());
    }
    // classical scalar PD
    {
        let s = scenarios::by_name("chain8-below");
        let mut p = ClassicalPath::new(Box::new(PdSampler::new(&s.graph)), 0xB002);
        let t0 = Instant::now();
        let r = validate(&mut p, &s.graph, s.name, &GateConfig::with_budget(4096, s.tau));
        all_passed &= r.passed();
        push(&mut report, &r, t0.elapsed().as_secs_f64());
    }
    // lane engine, both stable kernels, incl. the dense no-coloring case
    for (scenario, kernel) in [
        ("grid3x3-below", KernelKind::Scalar),
        ("grid3x3-below", KernelKind::Tiled),
        ("kn10-dense", KernelKind::Tiled),
    ] {
        let s = scenarios::by_name(scenario);
        let mut p = LanePath::new(
            s.graph.clone(),
            pdgibbs::engine::EngineConfig {
                lanes: 64,
                seed: 0xB003,
                kernel,
                ..Default::default()
            },
            None,
        );
        let t0 = Instant::now();
        let r = validate(&mut p, &s.graph, s.name, &GateConfig::with_budget(8192, s.tau));
        all_passed &= r.passed();
        push(&mut report, &r, t0.elapsed().as_secs_f64());
    }
    // the live coordinator serving path (marginal gate)
    {
        let s = scenarios::by_name("grid3x3-below");
        let mut p = CoordinatorPath::new(s.graph.clone(), 2, 0, 8, 0xB004);
        let t0 = Instant::now();
        let r = validate(&mut p, &s.graph, s.name, &GateConfig::with_budget(4096, s.tau));
        all_passed &= r.passed();
        push(&mut report, &r, t0.elapsed().as_secs_f64());
    }

    report.push(Record::new("validate-summary").metric(
        "all_passed",
        if all_passed { 1.0 } else { 0.0 },
    ));
    if !all_passed {
        println!("WARNING: statistical validation gates FAILED — see rows above");
    }
    report.finish_tracked("validate", "validate");
    if !all_passed {
        std::process::exit(1);
    }
}

// -- full mode --------------------------------------------------------------

fn bench_full() {
    let mut report = Report::new("throughput");
    let sweeps_per_rep = 20usize;

    for (wl, grid, g) in [
        ("grid50", "50x50", workloads::ising_grid(50, 50, 0.3, 0.0)),
        ("fc100", "fc100", workloads::fully_connected_ising(100, |_, _| 0.012)),
    ] {
        let n = g.num_vars() as f64;
        // sequential baseline
        let mut rng = Pcg64::seed(1);
        let mut seq = SequentialGibbs::new(&g);
        let times = time_fn(2, 10, || {
            for _ in 0..sweeps_per_rep {
                seq.sweep(&mut rng);
            }
        });
        push_sweep_metrics(&mut report, "sequential", wl, grid, &times, sweeps_per_rep, n, 0);

        // chromatic (single-thread and pooled)
        let mut chrom = ChromaticGibbs::new(&g);
        let times = time_fn(2, 10, || {
            for _ in 0..sweeps_per_rep {
                chrom.sweep(&mut rng);
            }
        });
        push_sweep_metrics(&mut report, "chromatic", wl, grid, &times, sweeps_per_rep, n, 0);

        // native PD across thread counts
        let max_threads = ThreadPool::default_size();
        let mut thread_counts = vec![0usize, 2, 4];
        if max_threads > 4 {
            thread_counts.push(max_threads);
        }
        for &t in &thread_counts {
            let mut pd = PdSampler::new(&g);
            if t > 0 {
                pd = pd.with_pool(Arc::new(ThreadPool::new(t)));
            }
            let times = time_fn(2, 10, || {
                for _ in 0..sweeps_per_rep {
                    pd.sweep(&mut rng);
                }
            });
            push_sweep_metrics(&mut report, "pd-native", wl, grid, &times, sweeps_per_rep, n, t);
        }
    }

    // XLA artifact path (needs `make artifacts` + `--features xla`)
    match Runtime::load("artifacts") {
        Ok(rt) => {
            for name in ["grid50", "fc100"] {
                let Some(meta) = rt.manifest().get(name).cloned() else { continue };
                let g = if name == "grid50" {
                    workloads::ising_grid(50, 50, 0.3, 0.0)
                } else {
                    workloads::fully_connected_ising(100, |_, _| 0.012)
                };
                let model = DualModel::from_graph(&g);
                let ops = model.dense_operands(meta.n_pad, meta.f_pad);
                let t0 = std::time::Instant::now();
                let exec = rt.chain_exec(name, &ops).expect("bind artifact");
                let compile_s = t0.elapsed().as_secs_f64();
                let mut state = exec.zero_state();
                let mut rng = Pcg64::seed(2);
                let times = time_fn(2, 10, || {
                    let key = [rng.next_u64() as u32, rng.next_u64() as u32];
                    let out = exec.run(&state, key).expect("chunk");
                    state = out.state;
                });
                let mean = times.iter().sum::<f64>() / times.len() as f64;
                // per-sweep cost must account for all chains advancing at once
                let sweeps = meta.sweeps as f64;
                report.push(
                    Record::new("pd-xla")
                        .param("workload", name)
                        .metric("chunk_s", mean)
                        .metric("sweeps_per_s", sweeps / mean)
                        .metric(
                            "chain_sweeps_per_s",
                            sweeps * meta.chains as f64 / mean,
                        )
                        .metric(
                            "Msite_updates_per_s",
                            sweeps * meta.chains as f64 * meta.n as f64 / mean / 1e6,
                        )
                        .metric("compile_s", compile_s),
                );
            }
        }
        Err(e) => println!("(xla path skipped: {e})"),
    }
    // own tracked file: must not clobber the lanes-mode acceptance record
    report.finish_tracked("throughput_full", "full");
}

#[allow(clippy::too_many_arguments)]
fn push_sweep_metrics(
    report: &mut Report,
    label: &str,
    wl: &str,
    grid: &str,
    times: &[f64],
    sweeps_per_rep: usize,
    n: f64,
    threads: usize,
) {
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    let per_sweep = mean / sweeps_per_rep as f64;
    report.push(
        Record::new(label)
            .param("workload", wl)
            .param("grid", grid)
            .param("threads", threads)
            .metric("sweep_ms", per_sweep * 1e3)
            .metric("sweeps_per_s", 1.0 / per_sweep)
            .metric("Msite_updates_per_s", n / per_sweep / 1e6),
    );
}
