//! §6 random-graph experiment: mixing vs factor-to-vertex ratio k.
//!
//! Paper setup: N = 1000 variables, F = k·N random factors with N(0,1)
//! log-potentials, k ∈ {2, 4, 8, 16, 32, 64}. Expected shape: the PD
//! sampler degrades as k grows (useful at k ≈ 2, not recommended at
//! k ≫ 2 unless factors are weak), while sequential Gibbs degrades much
//! more slowly.
//!
//! Default `quick` profile: N = 250, k ≤ 16, σ = 1.0 and a relaxed
//! threshold so the sweep budget stays tractable; `PDGIBBS_SCALE=full`
//! restores N = 1000 and the full k range.

use pdgibbs::bench::{Record, Report};
use pdgibbs::bench_support::{mixing_run, pick_monitors};
use pdgibbs::workloads;

fn main() {
    let full = std::env::var("PDGIBBS_SCALE").as_deref() == Ok("full");
    let (n, ks, max_sweeps, chains): (usize, &[usize], usize, usize) = if full {
        (1000, &[2, 4, 8, 16, 32, 64], 20_000, 10)
    } else {
        (250, &[2, 4, 8, 16], 8_000, 10)
    };
    let threshold = 1.05; // N(0,1) potentials are strong; 1.01 rarely
                          // certifies within budget even for sequential
    let mut report = Report::new(if full { "random_graphs_full" } else { "random_graphs" });
    println!("random graphs N={n}, F=kN, N(0,1) log-potentials, PSRF < {threshold}\n");
    for &k in ks {
        let g = workloads::random_graph(n, k, 1.0, 7_777);
        let monitors = pick_monitors(n, 16);
        let mut mixes = Vec::new();
        for kind in ["sequential", "pd"] {
            let r = mixing_run(&g, kind, chains, max_sweeps, threshold, &monitors, 31_337);
            let sweeps = r.mixing_time.map(|t| t as f64).unwrap_or(f64::NAN);
            mixes.push(sweeps);
            report.push(
                Record::new(kind)
                    .param("k", k)
                    .metric("mix_sweeps", sweeps)
                    .metric("final_psrf", r.final_psrf),
            );
        }
        if mixes.iter().all(|s| s.is_finite()) {
            report.push(
                Record::new("ratio pd/seq")
                    .param("k", k)
                    .metric("ratio", mixes[1] / mixes[0]),
            );
        }
        // weak-factor variant: the paper's caveat "if these factors are
        // not very weak" — at σ = 0.25 PD should stay usable at higher k
        let g_weak = workloads::random_graph(n, k, 0.25, 7_777);
        let r = mixing_run(&g_weak, "pd", chains, max_sweeps, threshold, &monitors, 31_337);
        report.push(
            Record::new("pd/weak(σ=0.25)")
                .param("k", k)
                .metric(
                    "mix_sweeps",
                    r.mixing_time.map(|t| t as f64).unwrap_or(f64::NAN),
                )
                .metric("final_psrf", r.final_psrf),
        );
    }
    report.finish();
}
