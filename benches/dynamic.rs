//! Dynamic-topology benchmark: the paper's motivating scenario, §1/§6.
//!
//! "It is hard to maintain a graph coloring in this setup" — quantified.
//! A churn trace streams factor insertions/removals; we measure, per
//! operation:
//!
//!   * PD path: dualize-on-insert (one 2×2 factorization + O(1) wiring)
//!   * chromatic path: coloring repair (touched variables + wall time),
//!     plus the *lost parallelism*: sweep width = variables / colors.
//!
//! Also measures end-to-end serving throughput of the coordinator under
//! churn (ops/s with continuous background sampling).

use std::time::Instant;

use pdgibbs::bench::{Record, Report};
use pdgibbs::coordinator::{Server, ServerConfig};
use pdgibbs::duality::DualModel;
use pdgibbs::graph::{coloring, FactorGraph};
use pdgibbs::workloads::ChurnTrace;

fn main() {
    let full = std::env::var("PDGIBBS_SCALE").as_deref() == Ok("full");
    let (vars, steps) = if full { (2000, 20_000) } else { (500, 5_000) };
    let mut report = Report::new("dynamic");

    for &(target, label) in &[(vars / 2, "sparse"), (vars * 2, "dense")] {
        let trace = ChurnTrace::generate(vars, target, steps, 0.5, 11);

        // -- PD maintenance --------------------------------------------
        let t0 = Instant::now();
        let mut g = FactorGraph::new(vars);
        let mut live = Vec::new();
        let mut model = DualModel::from_graph(&g);
        for op in &trace.ops {
            match *op {
                pdgibbs::workloads::ChurnOp::Add { v1, v2, beta } => {
                    let id = g.add_factor(pdgibbs::graph::PairFactor::ising(v1, v2, beta));
                    model.insert_at(id, g.factor(id).unwrap());
                    live.push(id);
                }
                pdgibbs::workloads::ChurnOp::RemoveLive { index } => {
                    let id = live.swap_remove(index);
                    g.remove_factor(id);
                    model.remove(id);
                }
            }
        }
        let pd_us = t0.elapsed().as_secs_f64() * 1e6 / steps as f64;
        report.push(
            Record::new("pd-maintenance")
                .param("density", label)
                .metric("us_per_op", pd_us)
                .metric("final_factors", g.num_factors() as f64),
        );

        // -- chromatic maintenance --------------------------------------
        let t0 = Instant::now();
        let mut g2 = FactorGraph::new(vars);
        let mut live2 = Vec::new();
        let mut col = coloring::greedy(&g2);
        let mut touched = 0usize;
        for op in &trace.ops {
            ChurnTrace::apply(&mut g2, &mut live2, op);
            touched += coloring::repair(&g2, &mut col);
        }
        assert!(col.is_proper(&g2));
        let chrom_us = t0.elapsed().as_secs_f64() * 1e6 / steps as f64;
        report.push(
            Record::new("chromatic-repair")
                .param("density", label)
                .metric("us_per_op", chrom_us)
                .metric("touched_vars", touched as f64)
                .metric("colors", col.num_colors as f64)
                .metric(
                    "parallel_width",
                    vars as f64 / col.num_colors as f64,
                ),
        );
        report.push(
            Record::new("maintenance-ratio")
                .param("density", label)
                .metric("chrom_over_pd", chrom_us / pd_us),
        );
    }

    // -- end-to-end serving under churn ---------------------------------
    let trace = ChurnTrace::generate(vars, vars, steps.min(2000), 0.4, 13);
    let mut server = Server::spawn(
        FactorGraph::new(vars),
        ServerConfig {
            chains: 10,
            background_sweeps: 4,
            ..Default::default()
        },
    );
    let h = server.handle();
    let t0 = Instant::now();
    for op in &trace.ops {
        h.apply(vec![op.clone()]);
    }
    let stats = h.stats().expect("server alive"); // barrier: all ops processed
    let dt = t0.elapsed().as_secs_f64();
    report.push(
        Record::new("coordinator-serving")
            .param("density", "steady")
            .metric("ops_per_s", stats.ops_applied as f64 / dt)
            .metric("sweeps_during_churn", stats.sweeps_done as f64),
    );
    server.shutdown();
    report.finish();
}
