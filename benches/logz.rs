//! §5.2 ablation: log-partition estimators vs exact values.
//!
//! On small grids (exact by enumeration) and medium grids (exact by
//! transfer matrix) we evaluate:
//!
//!   * `E[log V]` — the paper's lower bound from the PD chain,
//!   * `log mean V` — the unbiased (high-variance) estimator,
//!   * naive mean-field `−F` — the bound Lemma 5 predicts is usually
//!     tighter (the paper's own negative result).
//!
//! Expected shape: E[log V] ≤ log Z with a gap = 𝕀(x, θ); mean-field is
//! closer on weakly coupled models; the unbiased estimator is accurate on
//! tiny models and noisy on larger ones.

use pdgibbs::bench::{Record, Report};
use pdgibbs::duality::DualModel;
use pdgibbs::inference::{exact, mean_field, partition};
use pdgibbs::workloads;

fn main() {
    let mut report = Report::new("logz");
    for &(rows, cols, beta) in &[(3usize, 3usize, 0.2f64), (3, 3, 0.5), (4, 4, 0.3), (4, 5, 0.4)] {
        let g = workloads::ising_grid(rows, cols, beta, 0.1);
        let m = DualModel::from_graph(&g);
        let truth = exact::enumerate(&g).log_z;
        let offset = partition::dualization_log_scale(&g, &m);
        let est = partition::estimate_log_z(&m, 2_000, 30_000, 7);
        let mf = mean_field::naive(&g, 500, 1e-10);
        report.push(
            Record::new("grid")
                .param("size", format!("{rows}x{cols}"))
                .param("beta", beta)
                .metric("exact_logZ", truth)
                .metric("ElogV_bound", est.lower_bound + offset)
                .metric("logmeanV", est.log_mean_v + offset)
                .metric("meanfield_bound", -mf.free_energy)
                .metric("gap_ElogV", truth - (est.lower_bound + offset))
                .metric("gap_meanfield", truth + mf.free_energy),
        );
    }
    // larger grid: transfer-matrix exact log Z (16 rows max)
    for &(rows, cols, beta) in &[(8usize, 32usize, 0.25f64), (10, 50, 0.35)] {
        let g = workloads::ising_grid(rows, cols, beta, 0.0);
        let m = DualModel::from_graph(&g);
        let truth = exact::grid_transfer_matrix(rows, cols, beta, 0.0);
        let offset = partition::dualization_log_scale(&g, &m);
        let est = partition::estimate_log_z(&m, 1_000, 10_000, 9);
        let mf = mean_field::naive(&g, 300, 1e-9);
        report.push(
            Record::new("grid-tm")
                .param("size", format!("{rows}x{cols}"))
                .param("beta", beta)
                .metric("exact_logZ", truth)
                .metric("ElogV_bound", est.lower_bound + offset)
                .metric("meanfield_bound", -mf.free_energy)
                .metric("gap_ElogV", truth - (est.lower_bound + offset))
                .metric("gap_meanfield", truth + mf.free_energy),
        );
    }
    report.finish();
}
